"""GWTF's decentralized flow optimization (paper Sec. V-A / V-C).

Pipelines ("flows") are built *backwards* — from the sink (the data node a
microbatch must return to) toward the first stage — using three
message-passing subprotocols that rely only on local knowledge:

* **Request Flow**     — a node with spare capacity asks a subsequent-stage
  node with an *unpaired outflow* (committed downstream path, no upstream
  feeder yet) to connect; costs-to-sink propagate in reverse.
* **Request Change**   — two same-stage nodes swap their downstream peers
  when that lowers the objective (min-max edge cost).
* **Request Redirect** — a node with spare capacity interposes itself,
  replacing a peer on a 2-hop segment when that lowers cost.

Request Change / Redirect use simulated annealing (T=1.7, alpha=0.95 per
the paper): a worsening move is still accepted with probability
exp((cost_cur - cost_new)/T) > U(0,1).

Every decision here reads only (a) the deciding node's own state and (b)
state returned by an explicit query to a known peer — the global ``net``
object is used strictly as a message channel / cost oracle (d_ij is
measurable locally by the two endpoints).

Index structures (dirty-slot incremental maintenance)
-----------------------------------------------------
This implementation is behavior-preserving with respect to
``repro.core.flow.reference.ReferenceGWTFProtocol`` (the straightforward
per-round-scan implementation): the same seed produces the *identical*
flows and the identical RNG stream.  The speed comes from incremental
indexes over the protocol state, not from changing any decision:

* ``_unpaired[(j, dn)]`` — ordered map (keyed by segment append order) of
  node ``j``'s unpaired outflows toward data node ``dn``.
  Invariant: segment ``s`` owned by relay ``p`` is in
  ``_unpaired[(p.node_id, s.data_node)]`` **iff** ``s.upstream is None``.
  Kept current by the ``_append_segment`` / ``_remove_segment`` /
  ``_set_upstream`` mutation helpers — ``_advertised`` is an O(1) lookup
  instead of a scan of all of ``j``'s segments per query.
* ``_advertisers[dn]`` — the set of relay ids with at least one unpaired
  outflow toward ``dn``.  Invariant: ``j in _advertisers[dn]`` iff
  ``_unpaired[(j, dn)]`` is non-empty.  ``_request_flow`` consults it to
  reject peers in O(1) while still iterating ``known_next`` in the same
  order as the reference (ties in the strict ``<`` comparisons resolve
  identically).
* per-node unpaired counters (``ProtoNode.n_up_unpaired`` /
  ``n_down_unpaired``) — make ``stable()`` checks O(1); the set
  ``_broken`` (ids with ``n_down_unpaired > 0``) is the unpaired-inflow
  worklist: ``step_round`` only walks a node's segment list looking for
  repairs when the node is on it.
* ``_epoch[stage]`` — bumped by every segment mutation touching a relay
  of that stage.  When the annealing temperature has decayed below 1e-6
  (worsening moves rejected *without* consuming randomness), a
  Request Change / Redirect scan that found no improving move is memoised
  against the stage epoch and skipped until some same-stage state
  changes.  Scans consume no randomness before their annealed accepts
  (the per-round RNG block below), so memo hits stay stream-neutral.
* **dirty-slot candidate tables** (``_tbl[stage]``) — each stage keeps a
  position-aligned column store over its slot registry
  (``_stage_slot_buf[stage][:n]``): up/owner/down/data-node/order
  columns, the cached edge costs ``curR = d(up, owner) + d(owner,
  down)`` and ``w = d(owner, down)``, and the redirect/change validity
  masks.  The mutation helpers mark the touched slot's *position* dirty
  (``_mark_slot_dirty`` via the global ``_slot_pos`` slot→position
  map); ``_patch_stage`` re-gathers just the dirty positions on the
  next query.  An accepted refinement move therefore invalidates O(1)
  table rows instead of forcing an O(stage) rebuild — the epoch bumps
  survive only to key the frozen-regime memos above.  Full rebuilds
  remain the slow path behind three explicit triggers: registry
  compaction (positions shuffle), slot-buffer growth, and a
  cost-matrix refresh (cached edge costs go stale).  Candidate *sets*
  and their values are identical to a from-scratch rebuild, and the
  batched scans rank candidates by the unique (rotation rank, order
  stamp) key, so table row order cannot influence any decision.
  ``strict_rebuild=True`` keeps the pre-dirty-slot behavior — a full
  epoch-keyed table rebuild per mutated stage — as the in-engine
  equality oracle (``tests/test_flow_dirty_slots.py`` drives both modes
  through randomized mutation sequences and asserts table equality).
* ``_refresh_costs`` is an iterative stage-by-stage walk with
  deduplicated visits (a node's recompute is an idempotent function of
  its downstream values, so visiting each cone node once in
  downstream-first order produces the reference recursion's exact final
  values without its exponential revisit blowup).

Batched annealing engine (this PR's rebuild)
--------------------------------------------
The refinement hot loop is a *batched array program*:

* **Per-round RNG block.**  ``step_round`` draws the node-order shuffle
  plus ONE uniform block ``rng.random((len(order), 4))`` per round —
  source rotation, segment choice, and the two scan-visit rotations are
  *indexed* out of the block instead of drawn per node, so the stream
  position is a pure function of membership size (shared discipline
  with ``ReferenceGWTFProtocol``).
* **Segment slot arrays.**  Every relay-owned segment occupies a slot
  in flat NumPy arrays (``_seg_owner/_seg_up/_seg_down/_seg_dnode/
  _seg_ord``) kept current by the mutation helpers (O(1) scalar writes;
  per-stage slot registries with tombstones + lazy compaction).  A scan
  gathers its whole candidate set with a few vectorized ops instead of
  a Python walk over peer segment lists.
* **Vectorized scans.**  Frozen regime: "first improving candidate in
  rotation order" is one masked argmin — no fallthrough rescans.
  Annealing regime: the non-improving prefix's acceptance uniforms are
  drawn as one sized block (bit-identical to the reference's scalar
  draws), accepts are prefiltered with ``np.exp`` under a conservative
  margin and confirmed with ``math.exp`` (the reference's function), and
  unused draws are returned to the stream with ``bit_generator.advance``
  so the stream stays exactly aligned.
* ``strict_rng=True`` selects the scalar scan implementation (same
  stream, same flows — the compatibility oracle inside the optimized
  engine); the default batched mode is gated on flow-equality and in
  practice reproduces the reference stream bit-for-bit as well.

Cost queries go through a flattened copy of the dense cost matrix
(``FlowNetwork.cost_matrix()`` or the explicit ``cost_matrix`` argument),
refreshed when the network's cost-cache version changes.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from bisect import bisect_left, insort
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork, Node

_EMPTY_F = np.empty(0)
_EMPTY_SLOTS = np.empty(0, np.intp)
_EMPTY_I = np.empty(0, np.int64)
_EMPTY_B = np.empty(0, bool)


class _StageTable:
    """Dirty-slot candidate column store of one stage.

    Columns are aligned with positions ``0..n-1`` of the stage's slot
    registry (``_stage_slot_buf[stage]``); ``dirty`` holds positions
    whose columns are stale, ``rebuild`` forces a from-scratch refill
    (set on registry compaction, slot-buffer growth, or cost refresh).
    ``ver`` bumps whenever a patch changed anything — a cheap identity
    for downstream caches.
    """
    __slots__ = ("n", "ver", "rebuild", "dirty", "A", "B", "C", "dn",
                 "ords", "curR", "w", "validR", "validC")

    def __init__(self):
        self.n = 0
        self.ver = 0
        self.rebuild = True
        self.dirty: Set[int] = set()
        self.A = None       # upstream peer (-1 = unpaired)
        self.B = None       # owner
        self.C = None       # downstream peer (-1 = unpaired)
        self.dn = None      # the flow's data node
        self.ords = None    # append-order stamp
        self.curR = None    # d(A,B) + d(B,C) where validR
        self.w = None       # d(B,C) where validC
        self.validR = None  # live & fully paired -> redirect candidate
        self.validC = None  # live & non-sink downstream -> change candidate


@dataclass(eq=False)
class Segment:
    """One unit of flow through one node.

    ``eq=False``: segments are compared by identity — two segments of
    different flows can transiently carry identical field values, and
    list removal / membership must target the exact object.
    """
    flow_id: int
    data_node: int               # the sink this flow must return to
    downstream: Optional[int]    # next-stage peer (the sink itself for last stage)
    upstream: Optional[int]      # previous-stage feeder (None = unpaired outflow)
    cost_to_sink: float          # d(self, downstream) + downstream cost


@dataclass
class ProtoNode:
    """Local protocol state of one participant.

    ``n_up_unpaired`` / ``n_down_unpaired`` count segments with a missing
    upstream / downstream peer; the optimized protocol maintains them via
    its mutation helpers so ``stable()``-style checks are O(1).  The
    scan-based methods below remain the semantic definitions (and are
    what the reference implementation uses).
    """
    node_id: int
    stage: int                   # -1 for the data node's source side
    capacity: int
    known_next: Set[int] = field(default_factory=set)   # peers in stage+1 (or sink)
    known_same: Set[int] = field(default_factory=set)
    segments: List[Segment] = field(default_factory=list)
    alive: bool = True
    n_up_unpaired: int = 0
    n_down_unpaired: int = 0

    @property
    def used(self) -> int:
        return len(self.segments)

    @property
    def free(self) -> int:
        return self.capacity - self.used

    def unpaired_outflows(self) -> List[Segment]:
        return [s for s in self.segments if s.upstream is None]

    def stable(self) -> bool:
        return all(s.upstream is not None and s.downstream is not None
                   for s in self.segments)


class GWTFProtocol:
    """Round-based execution of the decentralized flow construction.

    ``peer_view`` limits each node's membership knowledge to a random
    subset of each adjacent stage (partial views, paper Sec. III); None
    means full adjacent-stage knowledge (as after long DHT gossip).
    ``refine=False`` disables the annealed Request Change / Redirect
    refinement (used by benchmarks to isolate its contribution).
    """

    def __init__(self, net: FlowNetwork, *,
                 cost_matrix: Optional[np.ndarray] = None,
                 temperature: float = 1.7, alpha: float = 0.95,
                 objective: str = "minmax",
                 peer_view: Optional[int] = None,
                 refine: bool = True,
                 strict_rng: bool = False,
                 strict_rebuild: bool = False,
                 rng: Optional[np.random.Generator] = None):
        self.net = net
        self.cost_matrix = cost_matrix
        self.T = temperature
        self.alpha = alpha
        self.objective = objective
        self.refine = refine
        self.strict_rng = strict_rng
        self.strict_rebuild = strict_rebuild
        self.rng = rng or np.random.default_rng(0)
        # the batched annealing prefix returns unused uniform draws via
        # bit_generator.advance(); generators without it (e.g. MT19937,
        # SFC64) fall back to per-candidate scalar draws — identical
        # stream, still-batched delta evaluation
        self._can_rewind = hasattr(self.rng.bit_generator, "advance")
        self.peer_view = peer_view
        self._flow_counter = itertools.count()
        self._order_counter = itertools.count()
        self.protos: Dict[int, ProtoNode] = {}
        self._sink_slots: Dict[int, int] = {}    # data node -> free sink slots
        # --- indexes (see module docstring for invariants) ---
        self._unpaired: Dict[Tuple[int, int], Dict[int, Segment]] = {}
        self._advertisers: Dict[int, Set[int]] = {}
        self._broken: Set[int] = set()           # unpaired-inflow worklist
        # _epoch[stage]: bumped by ANY segment mutation in the stage
        # (guards Request Redirect memos, which read upstream+downstream).
        # _epoch_down[(stage, dn)]: bumped only by downstream-pointer /
        # membership changes of that (stage, data_node) — the only state
        # a Request Change scan reads — so upstream-only pairings don't
        # spuriously invalidate change memos.
        self._epoch: Dict[int, int] = defaultdict(int)
        self._epoch_down: Dict[Tuple[int, int], int] = defaultdict(int)
        # _epoch_dn[stage]: bumped by downstream/membership mutations of
        # any (stage, dn) — i.e. exactly what the change candidate table
        # reads — so upstream-only pairings don't invalidate it.
        self._epoch_dn: Dict[int, int] = defaultdict(int)
        self._memo_change: Dict[Tuple[int, int], int] = {}
        self._memo_redirect: Dict[int, int] = {}
        # --- segment slot arrays (batched scan candidate store) ---
        # slot s of a live relay-owned segment: _seg_owner[s] = owner id
        # (-1 = tombstone), _seg_up/_seg_down = peer ids (-1 = unpaired),
        # _seg_dnode = the flow's data node, _seg_ord = the segment's
        # append-order stamp (ascending _seg_ord within an owner ==
        # segment-list order), _seg_objs[s] = the Segment object.
        cap0 = 256
        self._seg_owner = np.full(cap0, -1, np.int64)
        self._seg_up = np.full(cap0, -1, np.int64)
        self._seg_down = np.full(cap0, -1, np.int64)
        self._seg_dnode = np.full(cap0, -1, np.int64)
        self._seg_ord = np.zeros(cap0, np.int64)
        self._seg_objs: List[Optional[Segment]] = [None] * cap0
        self._seg_free: List[int] = []
        self._seg_top = 0
        # per-stage slot registries (append order, preallocated int
        # buffers; tombstones compacted lazily once they outnumber half
        # the registry)
        self._stage_slot_buf: Dict[int, np.ndarray] = {}
        self._stage_slot_n: Dict[int, int] = defaultdict(int)
        self._stage_dead: Dict[int, int] = defaultdict(int)
        self._stage_slots_ver: Dict[int, int] = defaultdict(int)
        self._cand_cache_r: Dict[int, tuple] = {}
        self._cand_cache_c: Dict[int, tuple] = {}
        # dirty-slot candidate tables (see module docstring): the
        # slot→position map plus one _StageTable of columns per stage,
        # patched in place at the dirty positions on query.
        self._slot_pos = np.full(cap0, -1, np.intp)
        self._tbl: Dict[int, _StageTable] = {}
        # sorted per-stage membership lists: _stage_alive[s] == the sorted
        # alive relay ids of stage s (== any member's known_same + itself);
        # _stage_with_segs[s] == the subset that currently carries >=1
        # segment.  They let the refinement scans take their candidate
        # lists in O(stage) slicing instead of sorted(genexpr) per call.
        # The *_ver counters key cached ndarray views of both lists.
        self._stage_alive: Dict[int, List[int]] = defaultdict(list)
        self._stage_with_segs: Dict[int, List[int]] = defaultdict(list)
        self._alive_ver: Dict[int, int] = defaultdict(int)
        self._wseg_ver: Dict[int, int] = defaultdict(int)
        self._alive_arr_cache: Dict[int, tuple] = {}
        self._wseg_arr_cache: Dict[int, tuple] = {}
        self._order_cache: Optional[np.ndarray] = None   # sorted proto ids
        # dense advertised-cost vectors: _adv_cost[dn][j] == the cheapest
        # cost-to-sink j advertises toward dn (+inf when none), kept
        # current by _adv_update at every advertisement mutation; and
        # per-node known_next snapshots in set-iteration order (the
        # reference's scan order), used to vectorize _best_advertiser.
        self._adv_cost: Dict[int, np.ndarray] = {}
        self._known_arr: Dict[int, np.ndarray] = {}
        self._data_ids: List[int] = [n.id for n in net.data_nodes()]
        self._data_set: Set[int] = set(self._data_ids)
        n_ids = (max(net.nodes) + 1) if net.nodes else 0
        self._is_data_arr = np.zeros(n_ids, bool)
        for d in self._data_ids:
            self._is_data_arr[d] = True
        self._cml: Optional[List[List[float]]] = None
        self._cml_ver: Optional[int] = None
        self._refresh_cost_source()
        self._build_protocol_state()

    # ------------------------------------------------------------------
    # Cost oracle
    # ------------------------------------------------------------------
    def _refresh_cost_source(self):
        """(Re)flatten the dense cost matrix into nested Python lists —
        the innermost ``d()`` lookup then avoids ndarray scalar boxing."""
        if self.cost_matrix is not None:
            if self._cml is None:
                self._cm_np = np.asarray(self.cost_matrix, dtype=float)
                self._cml = self._cm_np.tolist()
            return
        ver = self.net.cost_version
        if self._cml is None or self._cml_ver != ver:
            self._cml = self.net.cost_matrix().tolist()
            self._cm_np = self.net.cost_matrix()
            self._cml_ver = ver
            # cost changes invalidate every memoised refinement scan and
            # the candidate tables' cached edge costs
            self._memo_change.clear()
            self._memo_redirect.clear()
            self._cand_cache_r.clear()
            self._cand_cache_c.clear()
            for tbl in self._tbl.values():
                tbl.rebuild = True
                tbl.dirty.clear()

    def d(self, i: int, j: int) -> float:
        return self._cml[i][j]

    def _build_protocol_state(self):
        S = self.net.num_stages
        # one pass over the (insertion-ordered) node table gives per-stage
        # id lists in exactly net.stage_nodes() order, so the known_* sets
        # below have the same insertion history — and therefore the same
        # iteration order — as the reference implementation's.
        stage_ids: Dict[int, List[int]] = defaultdict(list)
        data_alive: List[int] = []
        for n in self.net.nodes.values():
            if n.is_data:
                if n.alive:
                    data_alive.append(n.id)
            elif n.alive:
                stage_ids[n.stage].append(n.id)
        for n in self.net.nodes.values():
            if not n.alive:
                continue
            p = ProtoNode(n.id, n.stage, n.capacity)
            self.protos[n.id] = p
        for s, ids in stage_ids.items():
            self._stage_alive[s] = sorted(ids)
        for p in self.protos.values():
            n = self.net.nodes[p.node_id]
            if n.is_data:
                self._sink_slots[n.id] = n.capacity
                nxt = set(stage_ids[0])
            elif n.stage == S - 1:
                nxt = set(data_alive)
            else:
                nxt = set(stage_ids[n.stage + 1])
            same = set(stage_ids[n.stage]) - {n.id}
            if self.peer_view is not None:
                nxt = set(self.rng.choice(sorted(nxt),
                                          size=min(self.peer_view, len(nxt)),
                                          replace=False)) if nxt else set()
            p.known_next = nxt
            p.known_same = same

    # ------------------------------------------------------------------
    # Index-maintaining mutation helpers.  Every segment-state mutation
    # in this class goes through these, which is what keeps the
    # _unpaired/_advertisers/_broken indexes and the per-stage epochs
    # consistent with the invariants in the module docstring.
    # ------------------------------------------------------------------
    def _touch(self, p: ProtoNode):
        if p.stage >= 0:
            self._epoch[p.stage] += 1

    def _touch_down(self, p: ProtoNode, data_node: int):
        if p.stage >= 0:
            self._epoch_down[(p.stage, data_node)] += 1
            self._epoch_dn[p.stage] += 1

    # -- segment slot store (see module docstring) ----------------------
    def _slot_alloc(self) -> int:
        if self._seg_free:
            return self._seg_free.pop()
        if self._seg_top == len(self._seg_owner):
            new = 2 * len(self._seg_owner)
            for name in ("_seg_owner", "_seg_up", "_seg_down",
                         "_seg_dnode", "_seg_ord"):
                old = getattr(self, name)
                arr = np.full(new, -1, np.int64) if name != "_seg_ord" \
                    else np.zeros(new, np.int64)
                arr[:self._seg_top] = old[:self._seg_top]
                setattr(self, name, arr)
            pos = np.full(new, -1, np.intp)
            pos[:self._seg_top] = self._slot_pos[:self._seg_top]
            self._slot_pos = pos
            self._seg_objs.extend([None] * (new - len(self._seg_objs)))
        slot = self._seg_top
        self._seg_top += 1
        return slot

    def _slot_add(self, p: ProtoNode, seg: Segment):
        slot = self._slot_alloc()
        seg._slot = slot
        self._seg_owner[slot] = p.node_id
        self._seg_up[slot] = -1 if seg.upstream is None else seg.upstream
        self._seg_down[slot] = -1 if seg.downstream is None else seg.downstream
        self._seg_dnode[slot] = seg.data_node
        self._seg_ord[slot] = seg._order
        self._seg_objs[slot] = seg
        stage = p.stage
        buf = self._stage_slot_buf.get(stage)
        n = self._stage_slot_n[stage]
        if buf is None or n == len(buf):
            grown = np.empty(max(64, 2 * (0 if buf is None else len(buf))),
                             np.intp)
            if buf is not None:
                grown[:n] = buf[:n]
            buf = self._stage_slot_buf[stage] = grown
        buf[n] = slot
        self._stage_slot_n[stage] = n + 1
        self._stage_slots_ver[stage] += 1
        self._slot_pos[slot] = n
        self._mark_slot_dirty(stage, slot)

    def _slot_drop(self, p: ProtoNode, seg: Segment):
        slot = getattr(seg, "_slot", -1)
        if slot < 0:
            return
        stage = p.stage
        self._mark_slot_dirty(stage, slot)
        self._seg_owner[slot] = -1           # tombstone
        self._seg_objs[slot] = None
        seg._slot = -1
        dead = self._stage_dead[stage] + 1
        n = self._stage_slot_n[stage]
        if dead > 16 and 2 * dead > n:
            buf = self._stage_slot_buf[stage]
            used = buf[:n]
            live = used[self._seg_owner[used] >= 0]
            self._seg_free.extend(used[self._seg_owner[used] < 0].tolist())
            k = len(live)
            buf[:k] = live
            self._stage_slot_n[stage] = k
            self._stage_dead[stage] = 0
            self._stage_slots_ver[stage] += 1
            # positions shuffled: remap the slot→position index and fall
            # back to a full table rebuild (dirty marks are meaningless
            # across a compaction, so they are discarded with it)
            self._slot_pos[live] = np.arange(k, dtype=np.intp)
            tbl = self._tbl.get(stage)
            if tbl is not None:
                tbl.rebuild = True
                tbl.dirty.clear()
        else:
            self._stage_dead[stage] = dead

    def _stage_slot_arr(self, stage: int) -> np.ndarray:
        buf = self._stage_slot_buf.get(stage)
        if buf is None:
            return _EMPTY_SLOTS
        return buf[:self._stage_slot_n[stage]]

    # -- dirty-slot candidate tables (see module docstring) -------------
    def _mark_slot_dirty(self, stage: int, slot: int):
        tbl = self._tbl.get(stage)
        if tbl is not None and not tbl.rebuild:
            tbl.dirty.add(int(self._slot_pos[slot]))

    def _tbl_fill(self, tbl: _StageTable, P: np.ndarray, slots: np.ndarray):
        """Refill the table columns at positions ``P`` ← slots ``slots``."""
        owner = self._seg_owner[slots]
        up = self._seg_up[slots]
        down = self._seg_down[slots]
        tbl.A[P] = up
        tbl.B[P] = owner
        tbl.C[P] = down
        tbl.dn[P] = self._seg_dnode[slots]
        tbl.ords[P] = self._seg_ord[slots]
        live = owner >= 0
        down_ok = down >= 0
        vr = live & (up >= 0) & down_ok
        vc = live & down_ok & ~self._is_data_arr[np.where(down_ok, down, 0)]
        tbl.validR[P] = vr
        tbl.validC[P] = vc
        cm = self._cm_np
        if vr.any():
            k = np.flatnonzero(vr)
            a, b, c = up[k], owner[k], down[k]
            tbl.curR[P[k]] = cm[a, b] + cm[b, c]
        if vc.any():
            k = np.flatnonzero(vc)
            tbl.w[P[k]] = cm[owner[k], down[k]]

    def _patch_stage(self, stage: int) -> _StageTable:
        """Bring the stage's candidate table current: O(#dirty) in the
        steady state, a full refill after compaction / growth / cost
        refresh."""
        tbl = self._tbl.get(stage)
        if tbl is None:
            tbl = self._tbl[stage] = _StageTable()
        buf = self._stage_slot_buf.get(stage)
        n = 0 if buf is None else self._stage_slot_n[stage]
        cap = 0 if buf is None else len(buf)
        if tbl.A is None or len(tbl.A) < cap:
            tbl.A = np.empty(cap, np.int64)
            tbl.B = np.empty(cap, np.int64)
            tbl.C = np.empty(cap, np.int64)
            tbl.dn = np.empty(cap, np.int64)
            tbl.ords = np.empty(cap, np.int64)
            tbl.curR = np.empty(cap)
            tbl.w = np.empty(cap)
            tbl.validR = np.zeros(cap, bool)
            tbl.validC = np.zeros(cap, bool)
            tbl.rebuild = True
        tbl.n = n
        if tbl.rebuild:
            if n:
                self._tbl_fill(tbl, np.arange(n, dtype=np.intp), buf[:n])
            tbl.dirty.clear()
            tbl.rebuild = False
            tbl.ver += 1
        elif tbl.dirty:
            P = np.fromiter(tbl.dirty, np.intp, len(tbl.dirty))
            tbl.dirty.clear()
            self._tbl_fill(tbl, P, buf[P])
            tbl.ver += 1
        return tbl

    def _alive_arr(self, stage: int) -> np.ndarray:
        ver = self._alive_ver[stage]
        cached = self._alive_arr_cache.get(stage)
        if cached is None or cached[0] != ver:
            cached = (ver, np.asarray(self._stage_alive[stage], np.int64))
            self._alive_arr_cache[stage] = cached
        return cached[1]

    def _wseg_arr(self, stage: int) -> np.ndarray:
        ver = self._wseg_ver[stage]
        cached = self._wseg_arr_cache.get(stage)
        if cached is None or cached[0] != ver:
            cached = (ver, np.asarray(self._stage_with_segs[stage], np.int64))
            self._wseg_arr_cache[stage] = cached
        return cached[1]

    def _adv_update(self, j: int, dn: int):
        """Refresh the dense advertised-cost entry for (j, dn)."""
        arr = self._adv_cost.get(dn)
        if arr is None:
            arr = self._adv_cost[dn] = np.full(len(self._is_data_arr),
                                               np.inf)
        idx = self._unpaired.get((j, dn))
        if idx:
            arr[j] = min(s.cost_to_sink for s in idx.values())
        else:
            arr[j] = np.inf

    def _index_add(self, p: ProtoNode, seg: Segment):
        key = (p.node_id, seg.data_node)
        idx = self._unpaired.get(key)
        if idx is None:
            idx = self._unpaired[key] = {}
        if not idx:
            self._advertisers.setdefault(seg.data_node, set()).add(p.node_id)
        idx[seg._order] = seg
        self._adv_update(p.node_id, seg.data_node)

    def _index_discard(self, p: ProtoNode, seg: Segment):
        key = (p.node_id, seg.data_node)
        idx = self._unpaired.get(key)
        if idx is not None and seg._order in idx:
            del idx[seg._order]
            if not idx:
                self._advertisers[seg.data_node].discard(p.node_id)
            self._adv_update(p.node_id, seg.data_node)

    def _append_segment(self, p: ProtoNode, seg: Segment):
        seg._order = next(self._order_counter)
        p.segments.append(seg)
        is_data = p.node_id in self._data_set
        if not is_data:
            if seg.upstream is None:
                p.n_up_unpaired += 1
                self._index_add(p, seg)
            if len(p.segments) == 1:
                insort(self._stage_with_segs[p.stage], p.node_id)
                self._wseg_ver[p.stage] += 1
            self._slot_add(p, seg)
        else:
            seg._slot = -1
        if seg.downstream is None:
            p.n_down_unpaired += 1
            self._broken.add(p.node_id)
        self._touch(p)
        self._touch_down(p, seg.data_node)

    def _remove_segment(self, p: ProtoNode, seg: Segment):
        p.segments.remove(seg)          # identity match (Segment eq=False)
        is_data = p.node_id in self._data_set
        if not is_data:
            if seg.upstream is None:
                p.n_up_unpaired -= 1
                self._index_discard(p, seg)
            if not p.segments:
                self._stage_with_segs[p.stage].remove(p.node_id)
                self._wseg_ver[p.stage] += 1
            self._slot_drop(p, seg)
            # evict the dead segment's memo entry so the cache stays
            # bounded by the number of live segments
            self._memo_change.pop((p.node_id, seg._order), None)
        if seg.downstream is None:
            p.n_down_unpaired -= 1
            if p.n_down_unpaired == 0:
                self._broken.discard(p.node_id)
        self._touch(p)
        self._touch_down(p, seg.data_node)

    def _set_upstream(self, p: ProtoNode, seg: Segment, up: Optional[int]):
        if seg.upstream is None and up is not None:
            if p.node_id not in self._data_set:
                p.n_up_unpaired -= 1
                self._index_discard(p, seg)
        elif seg.upstream is not None and up is None:
            if p.node_id not in self._data_set:
                p.n_up_unpaired += 1
                self._index_add(p, seg)
        seg.upstream = up
        slot = getattr(seg, "_slot", -1)
        if slot >= 0:
            self._seg_up[slot] = -1 if up is None else up
            self._mark_slot_dirty(p.stage, slot)
        self._touch(p)

    def _set_downstream(self, p: ProtoNode, seg: Segment, down: Optional[int]):
        if seg.downstream is None and down is not None:
            p.n_down_unpaired -= 1
            if p.n_down_unpaired == 0:
                self._broken.discard(p.node_id)
        elif seg.downstream is not None and down is None:
            p.n_down_unpaired += 1
            self._broken.add(p.node_id)
        seg.downstream = down
        slot = getattr(seg, "_slot", -1)
        if slot >= 0:
            self._seg_down[slot] = -1 if down is None else down
            self._mark_slot_dirty(p.stage, slot)
        self._touch(p)
        self._touch_down(p, seg.data_node)

    # ------------------------------------------------------------------
    # Queries (what a peer answers when asked — local information only)
    # ------------------------------------------------------------------
    def _advertised(self, j: int, data_node: int) -> Optional[float]:
        """Peer j's advertised cost-to-sink for an unpaired outflow to
        ``data_node``; None if it has none (infinite).  O(#unpaired at j
        for this sink) via the advertisement table."""
        if j in self._data_set:
            pj = self.protos.get(j)
            if pj is None or not pj.alive:
                return None
            return 0.0 if (j == data_node and self._sink_slots[j] > 0) else None
        idx = self._unpaired.get((j, data_node))
        if not idx:
            return None
        return min(s.cost_to_sink for s in idx.values())

    def _unpaired_in_list_order(self, j: int, data_node: int):
        """Unpaired outflows of j toward data_node, in segment-list
        (append) order — matches the reference's scan order exactly."""
        idx = self._unpaired.get((j, data_node))
        if not idx:
            return ()
        return [idx[k] for k in sorted(idx)]

    # ------------------------------------------------------------------
    # Request Flow
    # ------------------------------------------------------------------
    def _known_arr_of(self, i: int) -> np.ndarray:
        """``known_next`` snapshot in set-iteration order (the scan
        order of the reference's loop); invalidated on membership
        churn."""
        arr = self._known_arr.get(i)
        if arr is None:
            known = self.protos[i].known_next
            arr = np.fromiter(known, np.int64, len(known))
            self._known_arr[i] = arr
        return arr

    def _best_advertiser(self, i: int, data_node: int):
        """Cheapest known next-stage peer with an unpaired outflow toward
        ``data_node`` (or the sink itself), as (j, total, cost_to_sink).

        When the sink itself is not in view (every stage but the last),
        the scan is one vectorized argmin over the dense advertised-cost
        vector in ``known_next`` set order — ``np.argmin``'s
        first-minimum rule reproduces the reference loop's strict ``<``
        tie-breaking exactly.  Otherwise it falls back to the scalar
        scan.  Shared by _request_flow and _repair_downstream."""
        pi = self.protos[i]
        adv = self._advertisers.get(data_node)
        known = pi.known_next
        if ((not adv or adv.isdisjoint(known))
                and (data_node not in known
                     or self._sink_slots[data_node] <= 0)):
            return None, None, None
        if data_node not in known:
            arr = self._adv_cost.get(data_node)
            if arr is None:
                return None, None, None
            ks = self._known_arr_of(i)
            totals = arr[ks] + self._cm_np[i, ks]
            k = int(np.argmin(totals))
            total = totals[k]
            if total == np.inf:
                return None, None, None
            j = int(ks[k])
            return j, float(total), float(arr[j])
        best_j, best_total, best_cts = None, None, None
        row = self._cml[i]
        data_set = self._data_set
        for j in known:
            if j in data_set:
                if j != data_node or self._sink_slots[j] <= 0:
                    continue
                cts = 0.0
            else:
                idx = self._unpaired.get((j, data_node)) if adv and j in adv \
                    else None
                if not idx:
                    continue
                cts = min(s.cost_to_sink for s in idx.values())
            total = cts + row[j]
            if best_total is None or total < best_total:
                best_j, best_total, best_cts = j, total, cts
        return best_j, best_total, best_cts

    def _request_flow(self, i: int, data_node: int) -> bool:
        """Node i tries to pair with a subsequent-stage unpaired outflow."""
        pi = self.protos[i]
        best_j, _, best_cts = self._best_advertiser(i, data_node)
        if best_j is None:
            return False
        row = self._cml[i]
        # --- the Request Flow message exchange ---
        if best_j in self._data_set:
            if self._sink_slots[best_j] <= 0:
                return False
            self._sink_slots[best_j] -= 1
            fid = next(self._flow_counter)
            self._append_segment(pi, Segment(fid, data_node, best_j, None,
                                             row[best_j]))
            return True
        target = None
        for s in self._unpaired_in_list_order(best_j, data_node):
            if abs(s.cost_to_sink - best_cts) < 1e-9:
                target = s
                break
        if target is None:      # stale cost -> reject (requester retries next round)
            return False
        self._set_upstream(self.protos[best_j], target, i)
        self._append_segment(pi, Segment(target.flow_id, data_node, best_j, None,
                                         target.cost_to_sink + row[best_j]))
        return True

    # ------------------------------------------------------------------
    # Batched scan core.  A refinement scan visits a candidate list in
    # rotation order (sorted peers, random start offset) and resolves
    # the annealed accept/reject sequence.  The helpers below do that as
    # array programs over the segment slot store; outcomes and RNG
    # consumption are bit-identical to the scalar scans (strict_rng).
    # ------------------------------------------------------------------
    def _rotation_ranks(self, peers_arr: np.ndarray, self_id: int,
                        u_rot: float, owners: np.ndarray):
        """Visit rank of each candidate's owner under the rotation order
        over ``peers_arr`` minus ``self_id``.  Returns (ranks, n); n == 0
        means the scan has no peers at all."""
        n_all = len(peers_arr)
        pos_self = int(np.searchsorted(peers_arr, self_id))
        present = pos_self < n_all and peers_arr[pos_self] == self_id
        n = n_all - 1 if present else n_all
        if n <= 0:
            return None, 0
        start = int(u_rot * n)
        pos = np.searchsorted(peers_arr, owners)
        if present:
            pos = pos - (pos > pos_self)
        rank = pos - start
        rank[rank < 0] += n
        return rank, n

    def _redirect_cands(self, stage: int):
        """Request Redirect candidate table of a stage, full-length over
        the slot registry: (slot, A=up, B=owner, C=down,
        cur=d(A,B)+d(B,C), order stamp, valid mask).  Default mode reads
        the dirty-slot table (O(#dirty) maintenance); ``strict_rebuild``
        regathers everything from the slot store per mutated epoch — the
        in-engine equality oracle.  Rows where ``valid`` is False carry
        unspecified values."""
        if not self.strict_rebuild:
            tbl = self._patch_stage(stage)
            n = tbl.n
            if not n:
                return (_EMPTY_SLOTS, _EMPTY_I, _EMPTY_I, _EMPTY_I,
                        _EMPTY_F, _EMPTY_I, _EMPTY_B)
            return (self._stage_slot_buf[stage][:n], tbl.A[:n], tbl.B[:n],
                    tbl.C[:n], tbl.curR[:n], tbl.ords[:n], tbl.validR[:n])
        key = (self._epoch[stage], self._stage_slots_ver[stage])
        cached = self._cand_cache_r.get(stage)
        if cached is not None and cached[0] == key:
            return cached[1]
        slots = self._stage_slot_arr(stage)
        owner = self._seg_owner[slots]
        up = self._seg_up[slots]
        down = self._seg_down[slots]
        valid = (owner >= 0) & (up >= 0) & (down >= 0)
        if slots.size:
            cm = self._cm_np
            a = np.where(up >= 0, up, 0)
            b = np.where(owner >= 0, owner, 0)
            c = np.where(down >= 0, down, 0)
            cur = cm[a, b] + cm[b, c]
        else:
            cur = _EMPTY_F
        data = (slots, up, owner, down, cur, self._seg_ord[slots], valid)
        self._cand_cache_r[stage] = (key, data)
        return data

    def _change_cands(self, stage: int):
        """Request Change candidate table of a stage, full-length over
        the slot registry: (slot, J=owner, D=down, data node, w=d(J,D),
        order stamp, valid mask [live, downstream paired, non-sink]).
        Same dual-mode contract as ``_redirect_cands``; the strict path
        stays keyed on the downstream/membership epoch — upstream-only
        pairings leave it valid."""
        if not self.strict_rebuild:
            tbl = self._patch_stage(stage)
            n = tbl.n
            if not n:
                return (_EMPTY_SLOTS, _EMPTY_I, _EMPTY_I, _EMPTY_I,
                        _EMPTY_F, _EMPTY_I, _EMPTY_B)
            return (self._stage_slot_buf[stage][:n], tbl.B[:n], tbl.C[:n],
                    tbl.dn[:n], tbl.w[:n], tbl.ords[:n], tbl.validC[:n])
        key = (self._epoch_dn[stage], self._stage_slots_ver[stage])
        cached = self._cand_cache_c.get(stage)
        if cached is not None and cached[0] == key:
            return cached[1]
        slots = self._stage_slot_arr(stage)
        owner = self._seg_owner[slots]
        down = self._seg_down[slots]
        down_ok = down >= 0
        ds = np.where(down_ok, down, 0)
        valid = (owner >= 0) & down_ok & ~self._is_data_arr[ds]
        cm = self._cm_np
        wc = cm[np.where(owner >= 0, owner, 0), ds] if slots.size else _EMPTY_F
        data = (slots, owner, down, self._seg_dnode[slots], wc,
                self._seg_ord[slots], valid)
        self._cand_cache_c[stage] = (key, data)
        return data

    def _batched_pick(self, cur: np.ndarray, new: np.ndarray,
                      owners: np.ndarray, ords: np.ndarray,
                      peers_arr: np.ndarray, self_id: int,
                      u_rot: float) -> int:
        """Resolve a scan over the candidate arrays.  Returns the index
        (into cur/new) of the accepted candidate or -1, consuming
        acceptance uniforms exactly as the scalar scan: one per
        non-improving candidate visited before the accept (none when
        frozen)."""
        impr_u = new < cur
        if self.T <= 1e-6:                       # frozen: no draws at all
            # only the improving candidates matter; rank just them
            if not impr_u.any():
                return -1
            sub = np.flatnonzero(impr_u)
            rank, n = self._rotation_ranks(peers_arr, self_id, u_rot,
                                           owners[sub])
            if n <= 0:
                return -1
            k = np.lexsort((ords[sub], rank))[0]
            self.T *= self.alpha
            return int(sub[k])
        rank, n = self._rotation_ranks(peers_arr, self_id, u_rot, owners)
        if n <= 0:
            return -1
        order = np.lexsort((ords, rank))
        cur_o = cur[order]
        new_o = new[order]
        impr = impr_u[order]
        has_impr = bool(impr.any())
        fi = int(np.argmax(impr)) if has_impr else len(order)
        if fi and not self._can_rewind:
            # no advance(): draw the prefix uniforms one at a time (the
            # reference's exact consumption), deltas still vectorized
            xs = np.minimum((cur_o[:fi] - new_o[:fi]) / self.T,
                            0.0).tolist()
            uniform = self.rng.uniform
            for t, xv in enumerate(xs):
                if math.exp(xv) > uniform(0.0, 1.0):
                    self.T *= self.alpha
                    return int(order[t])
        elif fi:
            # the non-improving prefix: one uniform each, in visit order.
            # Sized draws produce the reference's exact scalar sequence;
            # np.exp can differ from the reference's math.exp by ~1 ulp,
            # so it only prefilters (with a conservative margin) and the
            # first plausible accept onward is confirmed with math.exp.
            u = self.rng.uniform(0.0, 1.0, size=fi)
            x = np.minimum((cur_o[:fi] - new_o[:fi]) / self.T, 0.0)
            maybe = u < np.exp(x) * (1.0 + 1e-12)
            if maybe.any():
                k0 = int(np.argmax(maybe))
                xl = x[k0:].tolist()
                ul = u[k0:].tolist()
                for t, xv in enumerate(xl):
                    if math.exp(xv) > ul[t]:
                        a = k0 + t
                        unused = fi - (a + 1)
                        if unused:       # return unconsumed draws
                            bg = self.rng.bit_generator
                            st = bg.state
                            bg.advance(-unused)
                            # advance() zeroes the buffered 32-bit half
                            # (has_uint32/uinteger) that bounded-integer
                            # draws (e.g. the round-order shuffle) leave
                            # behind; double draws never touch it, so
                            # restore it to keep the full state
                            # bit-identical to the scalar scan's.
                            st2 = bg.state
                            st2["has_uint32"] = st["has_uint32"]
                            st2["uinteger"] = st["uinteger"]
                            bg.state = st2
                        self.T *= self.alpha
                        return int(order[a])
        if has_impr:
            self.T *= self.alpha
            return int(order[fi])
        return -1

    # ------------------------------------------------------------------
    # Request Change (same-stage peer swap, annealed)
    # ------------------------------------------------------------------
    def _request_change(self, i: int, u_seg: float, u_rot: float) -> bool:
        pi = self.protos[i]
        if not pi.segments:
            return False
        si = pi.segments[int(u_seg * len(pi.segments))]
        si_dn = si.downstream
        if si_dn is None or si_dn in self._data_set:
            return False
        stage = pi.stage
        frozen = self.T <= 1e-6
        if frozen:
            # T is frozen: worsening moves are rejected without drawing
            # randomness, so a fruitless scan is a pure function of the
            # (stage, data_node) downstream state -> memoise against the
            # fine-grained epoch (a removed pair can never turn a
            # fruitless scan fruitful, so membership-only shrinkage
            # needs no bump).
            memo_key = (i, si._order)
            epoch_now = self._epoch_down[(stage, si.data_node)]
            if self._memo_change.get(memo_key) == epoch_now:
                return False
        if self.strict_rng:
            found = self._change_scan_scalar(i, pi, si, u_rot, frozen)
        else:
            found = self._change_scan_batched(i, pi, si, u_rot)
        if found:
            return True
        if frozen:
            self._memo_change[memo_key] = epoch_now
        return False

    def _change_scan_batched(self, i: int, pi: ProtoNode, si: Segment,
                             u_rot: float) -> bool:
        stage = pi.stage
        sc, Jc, Dc, dnc, wc, ordc, vc = self._change_cands(stage)
        if not sc.size:
            return False
        si_dn = si.downstream
        mask = vc & (Jc != i) & (dnc == si.data_node) & (Dc != si_dn)
        if not mask.any():
            return False
        idx = np.flatnonzero(mask)
        J = Jc[idx]
        D = Dc[idx]
        w = wc[idx]
        cm = self._cm_np
        a_cost = cm[i, si_dn]
        if self.objective == "sum":
            cur = a_cost + w
            new = cm[i, D] + cm[J, si_dn]
        else:
            cur = np.maximum(a_cost, w)
            new = np.maximum(cm[i, D], cm[J, si_dn])
        pick = self._batched_pick(cur, new, J, ordc[idx],
                                  self._alive_arr(stage), i, u_rot)
        if pick < 0:
            return False
        sj = self._seg_objs[sc[idx[pick]]]
        self._apply_change(i, pi, si, int(J[pick]), sj)
        return True

    def _change_scan_scalar(self, i: int, pi: ProtoNode, si: Segment,
                            u_rot: float, frozen: bool) -> bool:
        """strict_rng compatibility scan: the reference's per-candidate
        loop, visit order = sorted peers rotated by ``int(u_rot * n)``."""
        stage_lst = self._stage_alive[pi.stage]
        k_self = bisect_left(stage_lst, i)
        present = k_self < len(stage_lst) and stage_lst[k_self] == i
        candidates = (stage_lst[:k_self] + stage_lst[k_self + 1:]
                      if present else stage_lst)
        n = len(candidates)
        if n == 0:
            return False
        start = int(u_rot * n)
        # invariants of the scan, hoisted: si's fields cannot change until
        # an accept (which returns immediately), and T cannot cross the
        # frozen threshold mid-scan for the same reason.
        row_i = self._cml[i]
        data_set = self._data_set
        si_dn, si_data = si.downstream, si.data_node
        sum_obj = self.objective == "sum"
        a_cost = row_i[si_dn]
        protos = self.protos
        for k in range(n):
            t = start + k
            j = candidates[t if t < n else t - n]
            pj = protos[j]
            row_j = self._cml[j]
            rj_si = row_j[si_dn]
            for sj in pj.segments:
                sj_dn = sj.downstream
                if (sj.data_node != si_data or sj_dn is None
                        or sj_dn in data_set or sj_dn == si_dn):
                    continue
                if sum_obj:
                    cur = a_cost + row_j[sj_dn]
                    new = row_i[sj_dn] + rj_si
                else:
                    b = row_j[sj_dn]
                    cur = a_cost if a_cost > b else b
                    nx = row_i[sj_dn]
                    new = nx if nx > rj_si else rj_si
                # inlined _anneal_accept
                if new < cur:
                    self.T *= self.alpha
                elif frozen:
                    continue
                elif not self._anneal_worsening(cur, new):
                    continue
                self._apply_change(i, pi, si, j, sj)
                return True
        return False

    def _apply_change(self, i: int, pi: ProtoNode, si: Segment,
                      j: int, sj: Segment):
        """Accepted Request Change: swap downstream peers; inform the
        next-stage nodes (identical mutation order to the reference)."""
        pj = self.protos[j]
        si_dn, sj_dn = si.downstream, sj.downstream
        self._repoint_upstream(si_dn, old_up=i, new_up=j,
                               data_node=si.data_node)
        self._repoint_upstream(sj_dn, old_up=j, new_up=i,
                               data_node=sj.data_node)
        self._set_downstream(pi, si, sj_dn)
        self._set_downstream(pj, sj, si_dn)
        self._refresh_costs(i)
        self._refresh_costs(j)

    def _repoint_upstream(self, downstream_id: int, *, old_up: int,
                          new_up: Optional[int], data_node: int):
        pd = self.protos.get(downstream_id)
        if pd is None:
            return
        for s in pd.segments:
            if s.upstream == old_up and s.data_node == data_node:
                self._set_upstream(pd, s, new_up)
                return

    # ------------------------------------------------------------------
    # Request Redirect (node substitution, annealed)
    # ------------------------------------------------------------------
    def _request_redirect(self, m: int, u_rot: float) -> bool:
        """Spare node m offers to replace peer b on a chain a -> b -> c."""
        pm = self.protos[m]
        if pm.capacity <= len(pm.segments):      # == pm.free <= 0
            return False
        stage = pm.stage
        frozen = self.T <= 1e-6
        if frozen:
            epoch_now = self._epoch[stage]
            if self._memo_redirect.get(m) == epoch_now:
                return False
        if self.strict_rng:
            found = self._redirect_scan_scalar(m, pm, u_rot, frozen)
        else:
            found = self._redirect_scan_batched(m, pm, u_rot)
        if found:
            return True
        if frozen:
            self._memo_redirect[m] = epoch_now
        return False

    def _redirect_scan_batched(self, m: int, pm: ProtoNode,
                               u_rot: float) -> bool:
        stage = pm.stage
        sr, Ar, Br, Cr, cur_r, ordr, vr = self._redirect_cands(stage)
        if not sr.size:
            return False
        cm = self._cm_np
        mask = vr & (Br != m)
        if not mask.any():
            return False
        idx = np.flatnonzero(mask)
        sl = sr[idx]
        A = Ar[idx]
        B = Br[idx]
        C = Cr[idx]
        cur = cur_r[idx]
        ords = ordr[idx]
        new = cm[A, m] + cm[m, C]
        pick = self._batched_pick(cur, new, B, ords,
                                  self._wseg_arr(stage), m, u_rot)
        if pick < 0:
            return False
        sb = self._seg_objs[sl[pick]]
        self._apply_redirect(m, pm, int(B[pick]), sb)
        return True

    def _redirect_scan_scalar(self, m: int, pm: ProtoNode, u_rot: float,
                              frozen: bool) -> bool:
        """strict_rng compatibility scan (rotation visit order)."""
        # == sorted(j for j in pm.known_same if alive proto w/ segments)
        stage_lst = self._stage_with_segs[pm.stage]
        k_self = bisect_left(stage_lst, m)
        present = k_self < len(stage_lst) and stage_lst[k_self] == m
        peers = (stage_lst[:k_self] + stage_lst[k_self + 1:]
                 if present else stage_lst)
        n = len(peers)
        if n == 0:
            return False
        start = int(u_rot * n)
        row_m = self._cml[m]
        cml = self._cml
        protos = self.protos
        for k in range(n):
            t = start + k
            b = peers[t if t < n else t - n]
            pb = protos[b]
            row_b = cml[b]
            for sb in pb.segments:
                a = sb.upstream
                c = sb.downstream
                if a is None or c is None:
                    continue
                row_a = cml[a]
                cur = row_a[b] + row_b[c]
                new = row_a[m] + row_m[c]
                # inlined _anneal_accept
                if new < cur:
                    self.T *= self.alpha
                elif frozen:
                    continue
                elif not self._anneal_worsening(cur, new):
                    continue
                self._apply_redirect(m, pm, b, sb)
                return True
        return False

    def _apply_redirect(self, m: int, pm: ProtoNode, b: int, sb: Segment):
        """Accepted Request Redirect: b approves, m takes over the
        segment (identical mutation order to the reference)."""
        pb = self.protos[b]
        a, c = sb.upstream, sb.downstream
        row_m = self._cml[m]
        row_b = self._cml[b]
        self._remove_segment(pb, sb)
        seg = dataclasses.replace(
            sb, cost_to_sink=sb.cost_to_sink - row_b[c] + row_m[c])
        self._append_segment(pm, seg)
        # upstream a (may be the data node) and downstream c repoint
        pa = self.protos.get(a)
        if pa is not None:
            for s in pa.segments:
                if s.downstream == b and s.data_node == sb.data_node:
                    self._set_downstream(pa, s, m)
                    break
        if c not in self._data_set:
            self._repoint_upstream(c, old_up=b, new_up=m,
                                   data_node=sb.data_node)
        self._refresh_costs(m)

    def _anneal_accept(self, cur: float, new: float) -> bool:
        """Semantic definition of annealed acceptance.  The hot scans in
        _request_change/_request_redirect inline the improving/frozen
        branches and call _anneal_worsening directly — keep the three in
        sync (and in sync with ReferenceGWTFProtocol._anneal_accept)."""
        if new < cur:
            self.T *= self.alpha
            return True
        if self.T <= 1e-6:
            return False
        return self._anneal_worsening(cur, new)

    def _anneal_worsening(self, cur: float, new: float) -> bool:
        """Annealed acceptance of a non-improving move (T > 1e-6)."""
        p = math.exp(min((cur - new) / self.T, 0.0))
        if p > self.rng.uniform(0.0, 1.0):
            self.T *= self.alpha
            return True
        return False

    def _refresh_costs(self, i: int):
        """Recompute cost_to_sink for node i and propagate to feeders.

        Level-order propagation with the shared message-passing rules
        (see ``ReferenceGWTFProtocol._refresh_costs``): each wave node
        recomputes all its segments once, and only *changed* values are
        forwarded to the segment's feeder.  ``pair_map`` carries the
        previous level's just-recomputed (node, upstream, data_node) ->
        cost entries so the feeder resolves its downstream pairing in
        O(1); pairings outside the wave fall back to the reference's
        segment-list scan (first match wins) and read the same values.
        """
        data_set = self._data_set
        cml = self._cml
        protos = self.protos
        level = [i]
        seen = {i}
        pair_map: Dict[Tuple[int, int, int], float] = {}
        while level:
            nxt: List[int] = []
            new_pairs: Dict[Tuple[int, int, int], float] = {}
            setpair = new_pairs.setdefault
            for nid in level:
                pi = protos.get(nid)
                if pi is None:
                    continue
                row = cml[nid]
                for s in pi.segments:
                    sd = s.downstream
                    changed = False
                    if sd is not None:
                        if sd in data_set:
                            down_cost = 0.0
                        else:
                            down_cost = pair_map.get((sd, nid, s.data_node))
                            if down_cost is None:
                                down_cost = 0.0
                                pd = protos.get(sd)
                                if pd is not None:
                                    for seg_d in pd.segments:
                                        if (seg_d.upstream == nid
                                                and seg_d.data_node
                                                == s.data_node):
                                            down_cost = seg_d.cost_to_sink
                                            break
                        val = down_cost + row[sd]
                        if val != s.cost_to_sink:
                            s.cost_to_sink = val
                            changed = True
                            if s.upstream is None:
                                # an advertised (unpaired-outflow) cost
                                # moved: keep the dense vector current
                                self._adv_update(nid, s.data_node)
                    su = s.upstream
                    if su is not None and su not in data_set:
                        # record every pairing (first match in segment-
                        # list order wins, exactly like the scan — an
                        # earlier unchanged or unpaired-downstream
                        # segment must shadow a later changed one)
                        setpair((nid, su, s.data_node), s.cost_to_sink)
                        if changed and su not in seen:
                            seen.add(su)
                            nxt.append(su)
            level = nxt
            pair_map = new_pairs

    # ------------------------------------------------------------------
    # Round driver
    # ------------------------------------------------------------------
    def step_round(self) -> int:
        """One synchronous protocol round; returns number of state changes."""
        self._refresh_cost_source()
        changes = 0
        if self._order_cache is None:
            self._order_cache = np.asarray(sorted(self.protos))
        order = self._order_cache.copy()
        self.rng.shuffle(order)
        # the round's RNG block (shared discipline with the reference):
        # row k = (source rotation, segment choice, change rotation,
        # redirect rotation) for node order[k]; unused slots unread.
        block = self.rng.random((len(order), 4))
        data_set = self._data_set
        # liveness is static within a round: hoist the alive source list
        # the per-node rotations index into
        nodes = self.net.nodes
        alive_dns = [d for d in self._data_ids if nodes[d].alive]
        ndns = len(alive_dns)
        refine = self.refine
        protos = self.protos
        broken = self._broken
        adv_get = self._advertisers.get
        sink_slots = self._sink_slots
        request_flow = self._request_flow
        request_change = self._request_change
        request_redirect = self._request_redirect
        for k, i in enumerate(order.tolist()):
            pi = protos[i]
            if not pi.alive or i in data_set:
                continue
            if (pi.capacity > len(pi.segments)
                    and pi.n_up_unpaired == 0 and pi.n_down_unpaired == 0):
                if ndns > 1:
                    r = int(block[k, 0] * ndns)
                    dns = alive_dns[r:] + alive_dns[:r]
                else:
                    dns = alive_dns
                known = pi.known_next
                for dn in dns:
                    if pi.capacity <= len(pi.segments):
                        break
                    # inline fast-fail of _best_advertiser: no known
                    # advertiser and no reachable free sink slot
                    adv = adv_get(dn)
                    if ((not adv or adv.isdisjoint(known))
                            and (dn not in known or sink_slots[dn] <= 0)):
                        continue
                    if request_flow(i, dn):
                        changes += 1
            # nodes with unpaired inflow (downstream lost) re-pair downstream
            if i in broken:
                for s in list(pi.segments):
                    if s.downstream is None:
                        if self._repair_downstream(i, s):
                            s._deny_after = 3
                            changes += 1
                        else:
                            # DENY (Sec. V-D): if no alternate peer exists after
                            # a few attempts, release the segment and tell the
                            # upstream so the flow can be redistributed.
                            s._deny_after = getattr(s, "_deny_after", 3) - 1
                            if s._deny_after <= 0:
                                self._deny(i, s)
                                changes += 1
            # annealed refinement runs for every relay, every round
            # (paper Sec. V-C)
            if refine:
                if request_change(i, block[k, 1], block[k, 2]):
                    changes += 1
                if request_redirect(i, block[k, 3]):
                    changes += 1
        # data nodes also repair source-side segments whose downstream died
        for dn_id in self._data_ids:
            pd = self.protos.get(dn_id)
            if pd is None or dn_id not in self._broken:
                continue
            for s in list(pd.segments):
                if s.downstream is None:
                    self._remove_segment(pd, s)  # re-issue via _connect_sources
                    changes += 1
        # data nodes (source side) connect to stage-0 unpaired outflows
        changes += self._connect_sources()
        return changes

    def _repair_downstream(self, i: int, seg: Segment) -> bool:
        """Re-pair a segment whose downstream crashed (unpaired inflow)."""
        pi = self.protos[i]
        best_j, _, best_cts = self._best_advertiser(i, seg.data_node)
        if best_j is None:
            return False
        row = self._cml[i]
        if best_j in self._data_set:
            if self._sink_slots[best_j] <= 0:
                return False
            self._sink_slots[best_j] -= 1
            self._set_downstream(pi, seg, best_j)
            seg.cost_to_sink = row[best_j]
            if seg.upstream is None:
                self._adv_update(i, seg.data_node)
            return True
        for s in self._unpaired_in_list_order(best_j, seg.data_node):
            if abs(s.cost_to_sink - best_cts) < 1e-9:
                self._set_upstream(self.protos[best_j], s, i)
                self._set_downstream(pi, seg, best_j)
                seg.cost_to_sink = s.cost_to_sink + row[best_j]
                if seg.upstream is None:
                    self._adv_update(i, seg.data_node)
                return True
        return False

    def _deny(self, i: int, seg: Segment):
        """Drop an unrepairable segment and unpair its upstream feeder."""
        pi = self.protos.get(i)
        if pi is None or seg not in pi.segments:
            return
        up = seg.upstream
        self._remove_segment(pi, seg)
        if up is None:
            return
        pu = self.protos.get(up)
        if pu is None:
            return
        if up in self._data_set:
            # the source drops its segment and re-issues via connect_sources
            for su in list(pu.segments):
                if su.downstream == i and su.data_node == seg.data_node:
                    self._remove_segment(pu, su)
                    break
        else:
            for su in pu.segments:
                if su.downstream == i and su.data_node == seg.data_node:
                    self._set_downstream(pu, su, None)
                    break

    def _connect_sources(self) -> int:
        """Source side of each data node pairs with stage-0 unpaired outflows."""
        changes = 0
        for dn_id in self._data_ids:
            dn = self.net.nodes[dn_id]
            if not dn.alive:
                continue
            pd = self.protos[dn_id]
            row = self._cml[dn_id]
            while pd.used < pd.capacity:
                best = None
                adv = self._advertisers.get(dn_id)
                if adv and not adv.isdisjoint(pd.known_next):
                    for j in pd.known_next:
                        if j not in adv:
                            continue
                        for s in self._unpaired_in_list_order(j, dn_id):
                            total = s.cost_to_sink + row[j]
                            if best is None or total < best[0]:
                                best = (total, j, s)
                if best is None:
                    break
                _, j, s = best
                self._set_upstream(self.protos[j], s, dn_id)
                self._append_segment(pd, Segment(s.flow_id, dn_id, j, None,
                                                 best[0]))
                changes += 1
        return changes

    def run(self, max_rounds: int = 200, quiet_rounds: int = 25) -> int:
        quiet = 0
        r = 0
        for r in range(max_rounds):
            if self.step_round() == 0:
                quiet += 1
                if quiet >= quiet_rounds:
                    break
            else:
                quiet = 0
        return r + 1

    # ------------------------------------------------------------------
    # Results
    # ------------------------------------------------------------------
    def complete_flows(self) -> List[List[int]]:
        """Chains data_node -> s0 -> ... -> s(S-1) -> data_node."""
        chains = []
        visited = set()
        for dn_id in self._data_ids:
            pd = self.protos.get(dn_id)
            if pd is None:
                continue
            for seg in pd.segments:
                chain = [dn_id]
                prev, cur = dn_id, seg.downstream
                ok = True
                for _ in range(self.net.num_stages + 1):
                    if cur is None:
                        ok = False
                        break
                    chain.append(cur)
                    if cur == dn_id:
                        break
                    pc = self.protos.get(cur)
                    nxt = None
                    if pc is not None:
                        for s in pc.segments:
                            if (id(s) not in visited and s.upstream == prev
                                    and s.data_node == dn_id):
                                nxt = s.downstream
                                visited.add(id(s))
                                break
                    prev, cur = cur, nxt
                if ok and chain[-1] == dn_id and len(chain) == self.net.num_stages + 2:
                    chains.append(chain)
        return chains

    def flow_costs(self) -> List[float]:
        self._refresh_cost_source()
        costs = []
        for chain in self.complete_flows():
            c = sum(self.d(chain[k], chain[k + 1]) for k in range(len(chain) - 1))
            costs.append(c)
        return costs

    def total_cost(self) -> float:
        return float(sum(self.flow_costs()))

    def flow_codecs(self) -> List[List[str]]:
        """Per-edge chosen wire codec for every complete flow.

        Mirrors ``complete_flows()``: entry ``k`` of chain ``c`` is the
        codec the network priced edge ``(chain[k], chain[k+1])`` at, at
        the planner's activation size.  With an explicit external
        ``cost_matrix`` (abstract topologies) the menu is whatever the
        network carries — by construction fp32-only there.
        """
        names = self.net.wire_codec_names()
        choice = self.net.wire_codec_matrix()
        return [[names[choice[a, b]] for a, b in zip(chain, chain[1:])]
                for chain in self.complete_flows()]

    def max_edge_cost(self) -> float:
        self._refresh_cost_source()
        m = 0.0
        for chain in self.complete_flows():
            for k in range(len(chain) - 1):
                m = max(m, self.d(chain[k], chain[k + 1]))
        return m

    # ------------------------------------------------------------------
    # Churn hooks (used by the simulator)
    # ------------------------------------------------------------------
    def reclaim_sink_slots(self):
        """Recount free sink slots + garbage-collect stale segments.

        A segment left unpaired across two consecutive reclaim passes (one
        full iteration each) is dropped — the paper's "nodes that send
        DENY are excluded until they free memory" applied to dead flows.
        """
        self._gc_pass = getattr(self, "_gc_pass", 0) + 1
        for p in self.protos.values():
            if p.node_id in self._data_set:
                continue
            for s in list(p.segments):
                unpaired = s.upstream is None or s.downstream is None
                last = getattr(s, "_stale_since", None)
                if unpaired:
                    if last is None:
                        s._stale_since = self._gc_pass
                    elif self._gc_pass - last >= 2:
                        # free the memory; downstream/upstream unpair too
                        if s.downstream is not None:
                            self._repoint_upstream(s.downstream, old_up=p.node_id,
                                                   new_up=None,
                                                   data_node=s.data_node)
                        if s.upstream is not None:
                            pu = self.protos.get(s.upstream)
                            if pu is not None:
                                for su in pu.segments:
                                    if (su.downstream == p.node_id
                                            and su.data_node == s.data_node):
                                        self._set_downstream(pu, su, None)
                                        break
                        self._remove_segment(p, s)
                else:
                    s._stale_since = None
        for dn_id in self._data_ids:
            dn = self.net.nodes[dn_id]
            used = 0
            for p in self.protos.values():
                if p.node_id in self._data_set:
                    continue
                for s in p.segments:
                    if s.downstream == dn_id and s.data_node == dn_id:
                        used += 1
            self._sink_slots[dn_id] = max(0, dn.capacity - used)

    def remove_node(self, nid: int):
        """Crash: drop the node, unpair all segments that touched it."""
        p = self.protos.pop(nid, None)
        if p is None:
            return
        self._order_cache = None
        self._known_arr.clear()     # membership views change below
        if nid not in self._data_set:
            for seg in p.segments:
                if seg.upstream is None:
                    self._index_discard(p, seg)
                self._memo_change.pop((nid, seg._order), None)
                self._slot_drop(p, seg)
            self._memo_redirect.pop(nid, None)
            if p.stage >= 0:
                self._epoch[p.stage] += 1
                self._epoch_dn[p.stage] += 1
                alive = self._stage_alive[p.stage]
                k = bisect_left(alive, nid)
                if k < len(alive) and alive[k] == nid:
                    del alive[k]
                    self._alive_ver[p.stage] += 1
                if p.segments:
                    self._stage_with_segs[p.stage].remove(nid)
                    self._wseg_ver[p.stage] += 1
        self._broken.discard(nid)
        for other in self.protos.values():
            other.known_next.discard(nid)
            other.known_same.discard(nid)
            for s in other.segments:
                if s.downstream == nid:
                    self._set_downstream(other, s, None)  # re-pair later
                if s.upstream == nid:
                    self._set_upstream(other, s, None)    # unpaired outflow again
        # sink slots freed for flows that died with this node are reclaimed
        # lazily by the simulator between iterations.

    def add_node(self, node: Node):
        """Join: create protocol state with adjacent-stage views.

        Churn events are rare relative to rounds, so this mirrors the
        reference's O(N) membership walk; only the indexes and epochs
        need extra bookkeeping.
        """
        self._refresh_cost_source()
        S = self.net.num_stages
        p = ProtoNode(node.id, node.stage, node.capacity)
        if node.stage == S - 1:
            p.known_next = {m.id for m in self.net.data_nodes() if m.alive}
        else:
            p.known_next = {m.id for m in self.net.stage_nodes(node.stage + 1)}
        p.known_same = {m.id for m in self.net.stage_nodes(node.stage)} - {node.id}
        self.protos[node.id] = p
        self._order_cache = None
        self._known_arr.clear()     # membership views change below
        if node.id >= len(self._is_data_arr):
            new_n = max(node.id + 1, 2 * len(self._is_data_arr))
            grown = np.zeros(new_n, bool)
            grown[:len(self._is_data_arr)] = self._is_data_arr
            self._is_data_arr = grown
            for dn, arr in list(self._adv_cost.items()):
                big = np.full(new_n, np.inf)
                big[:len(arr)] = arr
                self._adv_cost[dn] = big
        if 0 <= node.stage:
            self._epoch[node.stage] += 1
            insort(self._stage_alive[node.stage], node.id)
            self._alive_ver[node.stage] += 1
        for other in self.protos.values():
            if other.node_id == node.id:
                continue
            on = self.net.nodes.get(other.node_id)
            if on is None:
                continue
            if on.stage == node.stage - 1 or (on.is_data and node.stage == 0):
                other.known_next.add(node.id)
            if on.stage == node.stage and not on.is_data:
                other.known_same.add(node.id)
            if on.is_data and node.stage == S - 1:
                p.known_next.add(on.id)
