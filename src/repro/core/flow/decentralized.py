"""GWTF's decentralized flow optimization (paper Sec. V-A / V-C).

Pipelines ("flows") are built *backwards* — from the sink (the data node a
microbatch must return to) toward the first stage — using three
message-passing subprotocols that rely only on local knowledge:

* **Request Flow**     — a node with spare capacity asks a subsequent-stage
  node with an *unpaired outflow* (committed downstream path, no upstream
  feeder yet) to connect; costs-to-sink propagate in reverse.
* **Request Change**   — two same-stage nodes swap their downstream peers
  when that lowers the objective (min-max edge cost).
* **Request Redirect** — a node with spare capacity interposes itself,
  replacing a peer on a 2-hop segment when that lowers cost.

Request Change / Redirect use simulated annealing (T=1.7, alpha=0.95 per
the paper): a worsening move is still accepted with probability
exp((cost_cur - cost_new)/T) > U(0,1).

Every decision here reads only (a) the deciding node's own state and (b)
state returned by an explicit query to a known peer — the global ``net``
object is used strictly as a message channel / cost oracle (d_ij is
measurable locally by the two endpoints).

Index structures (scale rebuild)
--------------------------------
This implementation is behavior-preserving with respect to
``repro.core.flow.reference.ReferenceGWTFProtocol`` (the straightforward
per-round-scan implementation): the same seed produces the *identical*
flows and the identical RNG stream.  The speed comes from incremental
indexes over the protocol state, not from changing any decision:

* ``_unpaired[(j, dn)]`` — ordered map (keyed by segment append order) of
  node ``j``'s unpaired outflows toward data node ``dn``.
  Invariant: segment ``s`` owned by relay ``p`` is in
  ``_unpaired[(p.node_id, s.data_node)]`` **iff** ``s.upstream is None``.
  Kept current by the ``_append_segment`` / ``_remove_segment`` /
  ``_set_upstream`` mutation helpers — ``_advertised`` is an O(1) lookup
  instead of a scan of all of ``j``'s segments per query.
* ``_advertisers[dn]`` — the set of relay ids with at least one unpaired
  outflow toward ``dn``.  Invariant: ``j in _advertisers[dn]`` iff
  ``_unpaired[(j, dn)]`` is non-empty.  ``_request_flow`` consults it to
  reject peers in O(1) while still iterating ``known_next`` in the same
  order as the reference (ties in the strict ``<`` comparisons resolve
  identically).
* per-node unpaired counters (``ProtoNode.n_up_unpaired`` /
  ``n_down_unpaired``) — make ``stable()`` checks O(1); the set
  ``_broken`` (ids with ``n_down_unpaired > 0``) is the unpaired-inflow
  worklist: ``step_round`` only walks a node's segment list looking for
  repairs when the node is on it.
* ``_epoch[stage]`` — bumped by every segment mutation touching a relay
  of that stage.  When the annealing temperature has decayed below 1e-6
  (worsening moves rejected *without* consuming randomness), a
  Request Change / Redirect scan that found no improving move is memoised
  against the stage epoch and skipped until some same-stage state
  changes.  The RNG draws that precede the scan (segment choice,
  candidate permutation) are still made, so the stream stays aligned
  with the reference.
* ``_refresh_costs`` is an iterative bounded-depth walk (explicit stack,
  depth capped at ``num_stages + 2``) instead of recursion — same final
  values, no recursion-limit exposure at deep pipelines.

Cost queries go through a flattened copy of the dense cost matrix
(``FlowNetwork.cost_matrix()`` or the explicit ``cost_matrix`` argument),
refreshed when the network's cost-cache version changes.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from bisect import bisect_left, insort
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork, Node


@dataclass(eq=False)
class Segment:
    """One unit of flow through one node.

    ``eq=False``: segments are compared by identity — two segments of
    different flows can transiently carry identical field values, and
    list removal / membership must target the exact object.
    """
    flow_id: int
    data_node: int               # the sink this flow must return to
    downstream: Optional[int]    # next-stage peer (the sink itself for last stage)
    upstream: Optional[int]      # previous-stage feeder (None = unpaired outflow)
    cost_to_sink: float          # d(self, downstream) + downstream cost


@dataclass
class ProtoNode:
    """Local protocol state of one participant.

    ``n_up_unpaired`` / ``n_down_unpaired`` count segments with a missing
    upstream / downstream peer; the optimized protocol maintains them via
    its mutation helpers so ``stable()``-style checks are O(1).  The
    scan-based methods below remain the semantic definitions (and are
    what the reference implementation uses).
    """
    node_id: int
    stage: int                   # -1 for the data node's source side
    capacity: int
    known_next: Set[int] = field(default_factory=set)   # peers in stage+1 (or sink)
    known_same: Set[int] = field(default_factory=set)
    segments: List[Segment] = field(default_factory=list)
    alive: bool = True
    n_up_unpaired: int = 0
    n_down_unpaired: int = 0

    @property
    def used(self) -> int:
        return len(self.segments)

    @property
    def free(self) -> int:
        return self.capacity - self.used

    def unpaired_outflows(self) -> List[Segment]:
        return [s for s in self.segments if s.upstream is None]

    def stable(self) -> bool:
        return all(s.upstream is not None and s.downstream is not None
                   for s in self.segments)


class GWTFProtocol:
    """Round-based execution of the decentralized flow construction.

    ``peer_view`` limits each node's membership knowledge to a random
    subset of each adjacent stage (partial views, paper Sec. III); None
    means full adjacent-stage knowledge (as after long DHT gossip).
    ``refine=False`` disables the annealed Request Change / Redirect
    refinement (used by benchmarks to isolate its contribution).
    """

    def __init__(self, net: FlowNetwork, *,
                 cost_matrix: Optional[np.ndarray] = None,
                 temperature: float = 1.7, alpha: float = 0.95,
                 objective: str = "minmax",
                 peer_view: Optional[int] = None,
                 refine: bool = True,
                 rng: Optional[np.random.Generator] = None):
        self.net = net
        self.cost_matrix = cost_matrix
        self.T = temperature
        self.alpha = alpha
        self.objective = objective
        self.refine = refine
        self.rng = rng or np.random.default_rng(0)
        self.peer_view = peer_view
        self._flow_counter = itertools.count()
        self._order_counter = itertools.count()
        self.protos: Dict[int, ProtoNode] = {}
        self._sink_slots: Dict[int, int] = {}    # data node -> free sink slots
        # --- indexes (see module docstring for invariants) ---
        self._unpaired: Dict[Tuple[int, int], Dict[int, Segment]] = {}
        self._advertisers: Dict[int, Set[int]] = {}
        self._broken: Set[int] = set()           # unpaired-inflow worklist
        # _epoch[stage]: bumped by ANY segment mutation in the stage
        # (guards Request Redirect memos, which read upstream+downstream).
        # _epoch_down[(stage, dn)]: bumped only by downstream-pointer /
        # membership changes of that (stage, data_node) — the only state
        # a Request Change scan reads — so upstream-only pairings don't
        # spuriously invalidate change memos.
        self._epoch: Dict[int, int] = defaultdict(int)
        self._epoch_down: Dict[Tuple[int, int], int] = defaultdict(int)
        # epoch-keyed vectorized views of the refinement search space:
        # _change_pairs[(stage, dn)] -> (epoch_down, J, D, w) arrays of
        # candidate (owner, downstream) pairs; _redirect_triples[stage]
        # -> (epoch, A, B, C, cur) arrays of (upstream, owner, downstream)
        # triples with their current 2-hop cost.  Used only in the frozen
        # regime to answer "can any improving move exist?" in a few numpy
        # ops; a positive answer falls through to the exact scalar scan.
        self._change_pairs: Dict[Tuple[int, int], tuple] = {}
        self._redirect_triples: Dict[int, tuple] = {}
        self._memo_change: Dict[Tuple[int, int], int] = {}
        self._memo_redirect: Dict[int, int] = {}
        # sorted per-stage membership lists: _stage_alive[s] == the sorted
        # alive relay ids of stage s (== any member's known_same + itself);
        # _stage_with_segs[s] == the subset that currently carries >=1
        # segment.  They let the refinement scans take their candidate
        # lists in O(stage) slicing instead of sorted(genexpr) per call.
        self._stage_alive: Dict[int, List[int]] = defaultdict(list)
        self._stage_with_segs: Dict[int, List[int]] = defaultdict(list)
        self._data_ids: List[int] = [n.id for n in net.data_nodes()]
        self._data_set: Set[int] = set(self._data_ids)
        self._cml: Optional[List[List[float]]] = None
        self._cml_ver: Optional[int] = None
        self._refresh_cost_source()
        self._build_protocol_state()

    # ------------------------------------------------------------------
    # Cost oracle
    # ------------------------------------------------------------------
    def _refresh_cost_source(self):
        """(Re)flatten the dense cost matrix into nested Python lists —
        the innermost ``d()`` lookup then avoids ndarray scalar boxing."""
        if self.cost_matrix is not None:
            if self._cml is None:
                self._cm_np = np.asarray(self.cost_matrix, dtype=float)
                self._cml = self._cm_np.tolist()
            return
        ver = self.net.cost_version
        if self._cml is None or self._cml_ver != ver:
            self._cml = self.net.cost_matrix().tolist()
            self._cm_np = self.net.cost_matrix()
            self._cml_ver = ver
            # cost changes invalidate every memoised refinement scan
            self._memo_change.clear()
            self._memo_redirect.clear()
            self._change_pairs.clear()
            self._redirect_triples.clear()

    def d(self, i: int, j: int) -> float:
        return self._cml[i][j]

    def _build_protocol_state(self):
        S = self.net.num_stages
        # one pass over the (insertion-ordered) node table gives per-stage
        # id lists in exactly net.stage_nodes() order, so the known_* sets
        # below have the same insertion history — and therefore the same
        # iteration order — as the reference implementation's.
        stage_ids: Dict[int, List[int]] = defaultdict(list)
        data_alive: List[int] = []
        for n in self.net.nodes.values():
            if n.is_data:
                if n.alive:
                    data_alive.append(n.id)
            elif n.alive:
                stage_ids[n.stage].append(n.id)
        for n in self.net.nodes.values():
            if not n.alive:
                continue
            p = ProtoNode(n.id, n.stage, n.capacity)
            self.protos[n.id] = p
        for s, ids in stage_ids.items():
            self._stage_alive[s] = sorted(ids)
        for p in self.protos.values():
            n = self.net.nodes[p.node_id]
            if n.is_data:
                self._sink_slots[n.id] = n.capacity
                nxt = set(stage_ids[0])
            elif n.stage == S - 1:
                nxt = set(data_alive)
            else:
                nxt = set(stage_ids[n.stage + 1])
            same = set(stage_ids[n.stage]) - {n.id}
            if self.peer_view is not None:
                nxt = set(self.rng.choice(sorted(nxt),
                                          size=min(self.peer_view, len(nxt)),
                                          replace=False)) if nxt else set()
            p.known_next = nxt
            p.known_same = same

    # ------------------------------------------------------------------
    # Index-maintaining mutation helpers.  Every segment-state mutation
    # in this class goes through these, which is what keeps the
    # _unpaired/_advertisers/_broken indexes and the per-stage epochs
    # consistent with the invariants in the module docstring.
    # ------------------------------------------------------------------
    def _touch(self, p: ProtoNode):
        if p.stage >= 0:
            self._epoch[p.stage] += 1

    def _touch_down(self, p: ProtoNode, data_node: int):
        if p.stage >= 0:
            self._epoch_down[(p.stage, data_node)] += 1

    def _index_add(self, p: ProtoNode, seg: Segment):
        key = (p.node_id, seg.data_node)
        idx = self._unpaired.get(key)
        if idx is None:
            idx = self._unpaired[key] = {}
        if not idx:
            self._advertisers.setdefault(seg.data_node, set()).add(p.node_id)
        idx[seg._order] = seg

    def _index_discard(self, p: ProtoNode, seg: Segment):
        key = (p.node_id, seg.data_node)
        idx = self._unpaired.get(key)
        if idx is not None and seg._order in idx:
            del idx[seg._order]
            if not idx:
                self._advertisers[seg.data_node].discard(p.node_id)

    def _append_segment(self, p: ProtoNode, seg: Segment):
        seg._order = next(self._order_counter)
        p.segments.append(seg)
        is_data = p.node_id in self._data_set
        if not is_data:
            if seg.upstream is None:
                p.n_up_unpaired += 1
                self._index_add(p, seg)
            if len(p.segments) == 1:
                insort(self._stage_with_segs[p.stage], p.node_id)
        if seg.downstream is None:
            p.n_down_unpaired += 1
            self._broken.add(p.node_id)
        self._touch(p)
        self._touch_down(p, seg.data_node)

    def _remove_segment(self, p: ProtoNode, seg: Segment):
        p.segments.remove(seg)          # identity match (Segment eq=False)
        is_data = p.node_id in self._data_set
        if not is_data:
            if seg.upstream is None:
                p.n_up_unpaired -= 1
                self._index_discard(p, seg)
            if not p.segments:
                self._stage_with_segs[p.stage].remove(p.node_id)
            # evict the dead segment's memo entry so the cache stays
            # bounded by the number of live segments
            self._memo_change.pop((p.node_id, seg._order), None)
        if seg.downstream is None:
            p.n_down_unpaired -= 1
            if p.n_down_unpaired == 0:
                self._broken.discard(p.node_id)
        self._touch(p)
        self._touch_down(p, seg.data_node)

    def _set_upstream(self, p: ProtoNode, seg: Segment, up: Optional[int]):
        if seg.upstream is None and up is not None:
            if p.node_id not in self._data_set:
                p.n_up_unpaired -= 1
                self._index_discard(p, seg)
        elif seg.upstream is not None and up is None:
            if p.node_id not in self._data_set:
                p.n_up_unpaired += 1
                self._index_add(p, seg)
        seg.upstream = up
        self._touch(p)

    def _set_downstream(self, p: ProtoNode, seg: Segment, down: Optional[int]):
        if seg.downstream is None and down is not None:
            p.n_down_unpaired -= 1
            if p.n_down_unpaired == 0:
                self._broken.discard(p.node_id)
        elif seg.downstream is not None and down is None:
            p.n_down_unpaired += 1
            self._broken.add(p.node_id)
        seg.downstream = down
        self._touch(p)
        self._touch_down(p, seg.data_node)

    # ------------------------------------------------------------------
    # Queries (what a peer answers when asked — local information only)
    # ------------------------------------------------------------------
    def _advertised(self, j: int, data_node: int) -> Optional[float]:
        """Peer j's advertised cost-to-sink for an unpaired outflow to
        ``data_node``; None if it has none (infinite).  O(#unpaired at j
        for this sink) via the advertisement table."""
        if j in self._data_set:
            pj = self.protos.get(j)
            if pj is None or not pj.alive:
                return None
            return 0.0 if (j == data_node and self._sink_slots[j] > 0) else None
        idx = self._unpaired.get((j, data_node))
        if not idx:
            return None
        return min(s.cost_to_sink for s in idx.values())

    def _unpaired_in_list_order(self, j: int, data_node: int):
        """Unpaired outflows of j toward data_node, in segment-list
        (append) order — matches the reference's scan order exactly."""
        idx = self._unpaired.get((j, data_node))
        if not idx:
            return ()
        return [idx[k] for k in sorted(idx)]

    # ------------------------------------------------------------------
    # Request Flow
    # ------------------------------------------------------------------
    def _best_advertiser(self, i: int, data_node: int):
        """Cheapest known next-stage peer with an unpaired outflow toward
        ``data_node`` (or the sink itself), as (j, total, cost_to_sink).

        Iterates ``known_next`` in set order with O(1) index rejections —
        the strict ``<`` tie-breaking matches the reference's full scan
        exactly.  Shared by _request_flow and _repair_downstream."""
        pi = self.protos[i]
        adv = self._advertisers.get(data_node)
        known = pi.known_next
        if ((not adv or adv.isdisjoint(known))
                and (data_node not in known
                     or self._sink_slots[data_node] <= 0)):
            return None, None, None
        best_j, best_total, best_cts = None, None, None
        row = self._cml[i]
        data_set = self._data_set
        for j in known:
            if j in data_set:
                if j != data_node or self._sink_slots[j] <= 0:
                    continue
                cts = 0.0
            else:
                idx = self._unpaired.get((j, data_node)) if adv and j in adv \
                    else None
                if not idx:
                    continue
                cts = min(s.cost_to_sink for s in idx.values())
            total = cts + row[j]
            if best_total is None or total < best_total:
                best_j, best_total, best_cts = j, total, cts
        return best_j, best_total, best_cts

    def _request_flow(self, i: int, data_node: int) -> bool:
        """Node i tries to pair with a subsequent-stage unpaired outflow."""
        pi = self.protos[i]
        best_j, _, best_cts = self._best_advertiser(i, data_node)
        if best_j is None:
            return False
        row = self._cml[i]
        # --- the Request Flow message exchange ---
        if best_j in self._data_set:
            if self._sink_slots[best_j] <= 0:
                return False
            self._sink_slots[best_j] -= 1
            fid = next(self._flow_counter)
            self._append_segment(pi, Segment(fid, data_node, best_j, None,
                                             row[best_j]))
            return True
        target = None
        for s in self._unpaired_in_list_order(best_j, data_node):
            if abs(s.cost_to_sink - best_cts) < 1e-9:
                target = s
                break
        if target is None:      # stale cost -> reject (requester retries next round)
            return False
        self._set_upstream(self.protos[best_j], target, i)
        self._append_segment(pi, Segment(target.flow_id, data_node, best_j, None,
                                         target.cost_to_sink + row[best_j]))
        return True

    # ------------------------------------------------------------------
    # Vectorized frozen-regime prefilters.  Both answer "does any
    # improving move exist?" from epoch-cached numpy views; they never
    # decide *which* move — a positive answer falls through to the exact
    # scalar scan, so outcomes and RNG consumption match the reference.
    # ------------------------------------------------------------------
    def _change_possible(self, stage: int, dn: int, i: int,
                         si_dn: int) -> bool:
        key = (stage, dn)
        ep = self._epoch_down[key]
        cached = self._change_pairs.get(key)
        if cached is None or cached[0] != ep:
            owners: List[int] = []
            downs: List[int] = []
            data_set = self._data_set
            for j in self._stage_with_segs[stage]:
                for sj in self.protos[j].segments:
                    d_j = sj.downstream
                    if (sj.data_node == dn and d_j is not None
                            and d_j not in data_set):
                        owners.append(j)
                        downs.append(d_j)
            J = np.asarray(owners, np.intp)
            D = np.asarray(downs, np.intp)
            w = self._cm_np[J, D] if J.size else np.empty(0)
            cached = (ep, J, D, w)
            self._change_pairs[key] = cached
        _, J, D, w = cached
        if not J.size:
            return False
        cm = self._cm_np
        a_cost = cm[i, si_dn]
        if self.objective == "sum":
            cur = a_cost + w
            new = cm[i, D] + cm[J, si_dn]
        else:
            cur = np.maximum(a_cost, w)
            new = np.maximum(cm[i, D], cm[J, si_dn])
        mask = new < cur
        mask &= D != si_dn
        mask &= J != i
        return bool(mask.any())

    def _redirect_possible(self, stage: int, m: int) -> bool:
        ep = self._epoch[stage]
        cached = self._redirect_triples.get(stage)
        if cached is None or cached[0] != ep:
            ups: List[int] = []
            owners: List[int] = []
            downs: List[int] = []
            for b in self._stage_with_segs[stage]:
                for sb in self.protos[b].segments:
                    if sb.upstream is not None and sb.downstream is not None:
                        ups.append(sb.upstream)
                        owners.append(b)
                        downs.append(sb.downstream)
            A = np.asarray(ups, np.intp)
            B = np.asarray(owners, np.intp)
            C = np.asarray(downs, np.intp)
            cur = (self._cm_np[A, B] + self._cm_np[B, C]) if A.size \
                else np.empty(0)
            cached = (ep, A, B, C, cur)
            self._redirect_triples[stage] = cached
        _, A, B, C, cur = cached
        if not A.size:
            return False
        cm = self._cm_np
        new = cm[A, m] + cm[m, C]
        mask = new < cur
        mask &= B != m
        return bool(mask.any())

    # ------------------------------------------------------------------
    # Request Change (same-stage peer swap, annealed)
    # ------------------------------------------------------------------
    def _request_change(self, i: int) -> bool:
        pi = self.protos[i]
        if not pi.segments:
            return False
        si = pi.segments[int(self.rng.integers(len(pi.segments)))]
        if si.downstream is None or si.downstream in self._data_set:
            return False
        # == sorted(j for j in pi.known_same if alive proto), via the
        # maintained per-stage membership list.  Only the *length* is
        # needed before the memo check, so the (O(stage)) exclusion copy
        # is deferred past it — memo hits never build the list.
        stage_lst = self._stage_alive[pi.stage]
        k_self = bisect_left(stage_lst, i)
        present = k_self < len(stage_lst) and stage_lst[k_self] == i
        perm = self.rng.permutation(len(stage_lst) - 1 if present
                                    else len(stage_lst))
        frozen = self.T <= 1e-6
        if frozen:
            # T is frozen: worsening moves are rejected without drawing
            # randomness, so a fruitless scan is a pure function of the
            # (stage, data_node) downstream state -> memoise against the
            # fine-grained epoch (a removed pair can never turn a
            # fruitless scan fruitful, so membership-only shrinkage
            # needs no bump).
            memo_key = (i, si._order)
            epoch_now = self._epoch_down[(pi.stage, si.data_node)]
            if self._memo_change.get(memo_key) == epoch_now:
                return False
            if not self._change_possible(pi.stage, si.data_node, i,
                                         si.downstream):
                self._memo_change[memo_key] = epoch_now
                return False
        candidates = (stage_lst[:k_self] + stage_lst[k_self + 1:]
                      if present else stage_lst)
        # invariants of the scan, hoisted: si's fields cannot change until
        # an accept (which returns immediately), and T cannot cross the
        # frozen threshold mid-scan for the same reason.
        row_i = self._cml[i]
        data_set = self._data_set
        si_dn, si_data = si.downstream, si.data_node
        sum_obj = self.objective == "sum"
        a_cost = row_i[si_dn]
        protos = self.protos
        for k in perm.tolist():
            j = candidates[k]
            pj = protos[j]
            row_j = self._cml[j]
            rj_si = row_j[si_dn]
            for sj in pj.segments:
                sj_dn = sj.downstream
                if (sj.data_node != si_data or sj_dn is None
                        or sj_dn in data_set or sj_dn == si_dn):
                    continue
                if sum_obj:
                    cur = a_cost + row_j[sj_dn]
                    new = row_i[sj_dn] + rj_si
                else:
                    b = row_j[sj_dn]
                    cur = a_cost if a_cost > b else b
                    nx = row_i[sj_dn]
                    new = nx if nx > rj_si else rj_si
                # inlined _anneal_accept
                if new < cur:
                    self.T *= self.alpha
                elif frozen:
                    continue
                elif not self._anneal_worsening(cur, new):
                    continue
                # swap downstream peers; inform next-stage nodes
                self._repoint_upstream(si_dn, old_up=i, new_up=j,
                                       data_node=si_data)
                self._repoint_upstream(sj_dn, old_up=j, new_up=i,
                                       data_node=sj.data_node)
                self._set_downstream(pi, si, sj_dn)
                self._set_downstream(pj, sj, si_dn)
                self._refresh_costs(i)
                self._refresh_costs(j)
                return True
        if frozen:
            self._memo_change[memo_key] = epoch_now
        return False

    def _repoint_upstream(self, downstream_id: int, *, old_up: int,
                          new_up: Optional[int], data_node: int):
        pd = self.protos.get(downstream_id)
        if pd is None:
            return
        for s in pd.segments:
            if s.upstream == old_up and s.data_node == data_node:
                self._set_upstream(pd, s, new_up)
                return

    # ------------------------------------------------------------------
    # Request Redirect (node substitution, annealed)
    # ------------------------------------------------------------------
    def _request_redirect(self, m: int) -> bool:
        """Spare node m offers to replace peer b on a chain a -> b -> c."""
        pm = self.protos[m]
        if pm.free <= 0:
            return False
        # == sorted(j for j in pm.known_same if alive proto w/ segments);
        # list construction deferred past the memo check (see
        # _request_change)
        stage_lst = self._stage_with_segs[pm.stage]
        k_self = bisect_left(stage_lst, m)
        present = k_self < len(stage_lst) and stage_lst[k_self] == m
        perm = self.rng.permutation(len(stage_lst) - 1 if present
                                    else len(stage_lst))
        frozen = self.T <= 1e-6
        if frozen:
            if self._memo_redirect.get(m) == self._epoch[pm.stage]:
                return False
            if not self._redirect_possible(pm.stage, m):
                self._memo_redirect[m] = self._epoch[pm.stage]
                return False
        peers = (stage_lst[:k_self] + stage_lst[k_self + 1:]
                 if present else stage_lst)
        row_m = self._cml[m]
        cml = self._cml
        protos = self.protos
        for k in perm.tolist():
            b = peers[k]
            pb = protos[b]
            row_b = cml[b]
            for sb in pb.segments:
                a = sb.upstream
                c = sb.downstream
                if a is None or c is None:
                    continue
                row_a = cml[a]
                cur = row_a[b] + row_b[c]
                new = row_a[m] + row_m[c]
                # inlined _anneal_accept
                if new < cur:
                    self.T *= self.alpha
                elif frozen:
                    continue
                elif not self._anneal_worsening(cur, new):
                    continue
                # b approves: m takes over the segment
                self._remove_segment(pb, sb)
                seg = dataclasses.replace(
                    sb, cost_to_sink=sb.cost_to_sink
                    - row_b[c] + row_m[c])
                self._append_segment(pm, seg)
                # upstream a (may be the data node) and downstream c repoint
                pa = protos.get(a)
                if pa is not None:
                    for s in pa.segments:
                        if s.downstream == b and s.data_node == sb.data_node:
                            self._set_downstream(pa, s, m)
                            break
                if c not in self._data_set:
                    self._repoint_upstream(c, old_up=b, new_up=m,
                                           data_node=sb.data_node)
                self._refresh_costs(m)
                return True
        if frozen:
            self._memo_redirect[m] = self._epoch[pm.stage]
        return False

    def _anneal_accept(self, cur: float, new: float) -> bool:
        """Semantic definition of annealed acceptance.  The hot scans in
        _request_change/_request_redirect inline the improving/frozen
        branches and call _anneal_worsening directly — keep the three in
        sync (and in sync with ReferenceGWTFProtocol._anneal_accept)."""
        if new < cur:
            self.T *= self.alpha
            return True
        if self.T <= 1e-6:
            return False
        return self._anneal_worsening(cur, new)

    def _anneal_worsening(self, cur: float, new: float) -> bool:
        """Annealed acceptance of a non-improving move (T > 1e-6)."""
        p = math.exp(min((cur - new) / self.T, 0.0))
        if p > self.rng.uniform(0.0, 1.0):
            self.T *= self.alpha
            return True
        return False

    def _refresh_costs(self, i: int):
        """Recompute cost_to_sink for node i and propagate to feeders.

        Iterative bounded-depth walk (upstream chains strictly decrease
        in stage, so depth <= num_stages + 1); replaces the reference's
        recursion with identical resulting values.
        """
        data_set = self._data_set
        cml = self._cml
        max_depth = self.net.num_stages + 2
        stack = [(i, 0)]
        while stack:
            nid, depth = stack.pop()
            pi = self.protos.get(nid)
            if pi is None:
                continue
            row = cml[nid]
            for s in pi.segments:
                if s.downstream is None:
                    continue
                down_cost = 0.0
                if s.downstream not in data_set:
                    pd = self.protos.get(s.downstream)
                    if pd is not None:
                        for sd in pd.segments:
                            if sd.upstream == nid and sd.data_node == s.data_node:
                                down_cost = sd.cost_to_sink
                                break
                s.cost_to_sink = down_cost + row[s.downstream]
            if depth + 1 >= max_depth:
                continue
            for s in pi.segments:
                if s.upstream is not None and s.upstream not in data_set:
                    stack.append((s.upstream, depth + 1))

    # ------------------------------------------------------------------
    # Round driver
    # ------------------------------------------------------------------
    def step_round(self) -> int:
        """One synchronous protocol round; returns number of state changes."""
        self._refresh_cost_source()
        changes = 0
        order = np.asarray(sorted(self.protos))
        self.rng.shuffle(order)
        data_set = self._data_set
        for i in order.tolist():
            pi = self.protos[i]
            if not pi.alive or i in data_set:
                continue
            if (pi.capacity > len(pi.segments)
                    and pi.n_up_unpaired == 0 and pi.n_down_unpaired == 0):
                for dn in self._known_data_nodes(i):
                    if pi.free <= 0:
                        break
                    if self._request_flow(i, dn):
                        changes += 1
            # nodes with unpaired inflow (downstream lost) re-pair downstream
            if i in self._broken:
                for s in list(pi.segments):
                    if s.downstream is None:
                        if self._repair_downstream(i, s):
                            s._deny_after = 3
                            changes += 1
                        else:
                            # DENY (Sec. V-D): if no alternate peer exists after
                            # a few attempts, release the segment and tell the
                            # upstream so the flow can be redistributed.
                            s._deny_after = getattr(s, "_deny_after", 3) - 1
                            if s._deny_after <= 0:
                                self._deny(i, s)
                                changes += 1
            # annealed refinement runs for every relay, every round
            # (paper Sec. V-C)
            if self.refine:
                if self._request_change(i):
                    changes += 1
                if self._request_redirect(i):
                    changes += 1
        # data nodes also repair source-side segments whose downstream died
        for dn_id in self._data_ids:
            pd = self.protos.get(dn_id)
            if pd is None or dn_id not in self._broken:
                continue
            for s in list(pd.segments):
                if s.downstream is None:
                    self._remove_segment(pd, s)  # re-issue via _connect_sources
                    changes += 1
        # data nodes (source side) connect to stage-0 unpaired outflows
        changes += self._connect_sources()
        return changes

    def _known_data_nodes(self, i: int) -> List[int]:
        dns = [d for d in self._data_ids if self.net.nodes[d].alive]
        self.rng.shuffle(dns)          # avoid fixed-priority source bias
        return dns

    def _repair_downstream(self, i: int, seg: Segment) -> bool:
        """Re-pair a segment whose downstream crashed (unpaired inflow)."""
        pi = self.protos[i]
        best_j, _, best_cts = self._best_advertiser(i, seg.data_node)
        if best_j is None:
            return False
        row = self._cml[i]
        if best_j in self._data_set:
            if self._sink_slots[best_j] <= 0:
                return False
            self._sink_slots[best_j] -= 1
            self._set_downstream(pi, seg, best_j)
            seg.cost_to_sink = row[best_j]
            return True
        for s in self._unpaired_in_list_order(best_j, seg.data_node):
            if abs(s.cost_to_sink - best_cts) < 1e-9:
                self._set_upstream(self.protos[best_j], s, i)
                self._set_downstream(pi, seg, best_j)
                seg.cost_to_sink = s.cost_to_sink + row[best_j]
                return True
        return False

    def _deny(self, i: int, seg: Segment):
        """Drop an unrepairable segment and unpair its upstream feeder."""
        pi = self.protos.get(i)
        if pi is None or seg not in pi.segments:
            return
        up = seg.upstream
        self._remove_segment(pi, seg)
        if up is None:
            return
        pu = self.protos.get(up)
        if pu is None:
            return
        if up in self._data_set:
            # the source drops its segment and re-issues via connect_sources
            for su in list(pu.segments):
                if su.downstream == i and su.data_node == seg.data_node:
                    self._remove_segment(pu, su)
                    break
        else:
            for su in pu.segments:
                if su.downstream == i and su.data_node == seg.data_node:
                    self._set_downstream(pu, su, None)
                    break

    def _connect_sources(self) -> int:
        """Source side of each data node pairs with stage-0 unpaired outflows."""
        changes = 0
        for dn_id in self._data_ids:
            dn = self.net.nodes[dn_id]
            if not dn.alive:
                continue
            pd = self.protos[dn_id]
            row = self._cml[dn_id]
            while pd.used < pd.capacity:
                best = None
                adv = self._advertisers.get(dn_id)
                if adv and not adv.isdisjoint(pd.known_next):
                    for j in pd.known_next:
                        if j not in adv:
                            continue
                        for s in self._unpaired_in_list_order(j, dn_id):
                            total = s.cost_to_sink + row[j]
                            if best is None or total < best[0]:
                                best = (total, j, s)
                if best is None:
                    break
                _, j, s = best
                self._set_upstream(self.protos[j], s, dn_id)
                self._append_segment(pd, Segment(s.flow_id, dn_id, j, None,
                                                 best[0]))
                changes += 1
        return changes

    def run(self, max_rounds: int = 200, quiet_rounds: int = 25) -> int:
        quiet = 0
        r = 0
        for r in range(max_rounds):
            if self.step_round() == 0:
                quiet += 1
                if quiet >= quiet_rounds:
                    break
            else:
                quiet = 0
        return r + 1

    # ------------------------------------------------------------------
    # Results
    # ------------------------------------------------------------------
    def complete_flows(self) -> List[List[int]]:
        """Chains data_node -> s0 -> ... -> s(S-1) -> data_node."""
        chains = []
        visited = set()
        for dn_id in self._data_ids:
            pd = self.protos.get(dn_id)
            if pd is None:
                continue
            for seg in pd.segments:
                chain = [dn_id]
                prev, cur = dn_id, seg.downstream
                ok = True
                for _ in range(self.net.num_stages + 1):
                    if cur is None:
                        ok = False
                        break
                    chain.append(cur)
                    if cur == dn_id:
                        break
                    pc = self.protos.get(cur)
                    nxt = None
                    if pc is not None:
                        for s in pc.segments:
                            if (id(s) not in visited and s.upstream == prev
                                    and s.data_node == dn_id):
                                nxt = s.downstream
                                visited.add(id(s))
                                break
                    prev, cur = cur, nxt
                if ok and chain[-1] == dn_id and len(chain) == self.net.num_stages + 2:
                    chains.append(chain)
        return chains

    def flow_costs(self) -> List[float]:
        self._refresh_cost_source()
        costs = []
        for chain in self.complete_flows():
            c = sum(self.d(chain[k], chain[k + 1]) for k in range(len(chain) - 1))
            costs.append(c)
        return costs

    def total_cost(self) -> float:
        return float(sum(self.flow_costs()))

    def max_edge_cost(self) -> float:
        self._refresh_cost_source()
        m = 0.0
        for chain in self.complete_flows():
            for k in range(len(chain) - 1):
                m = max(m, self.d(chain[k], chain[k + 1]))
        return m

    # ------------------------------------------------------------------
    # Churn hooks (used by the simulator)
    # ------------------------------------------------------------------
    def reclaim_sink_slots(self):
        """Recount free sink slots + garbage-collect stale segments.

        A segment left unpaired across two consecutive reclaim passes (one
        full iteration each) is dropped — the paper's "nodes that send
        DENY are excluded until they free memory" applied to dead flows.
        """
        self._gc_pass = getattr(self, "_gc_pass", 0) + 1
        for p in self.protos.values():
            if p.node_id in self._data_set:
                continue
            for s in list(p.segments):
                unpaired = s.upstream is None or s.downstream is None
                last = getattr(s, "_stale_since", None)
                if unpaired:
                    if last is None:
                        s._stale_since = self._gc_pass
                    elif self._gc_pass - last >= 2:
                        # free the memory; downstream/upstream unpair too
                        if s.downstream is not None:
                            self._repoint_upstream(s.downstream, old_up=p.node_id,
                                                   new_up=None,
                                                   data_node=s.data_node)
                        if s.upstream is not None:
                            pu = self.protos.get(s.upstream)
                            if pu is not None:
                                for su in pu.segments:
                                    if (su.downstream == p.node_id
                                            and su.data_node == s.data_node):
                                        self._set_downstream(pu, su, None)
                                        break
                        self._remove_segment(p, s)
                else:
                    s._stale_since = None
        for dn_id in self._data_ids:
            dn = self.net.nodes[dn_id]
            used = 0
            for p in self.protos.values():
                if p.node_id in self._data_set:
                    continue
                for s in p.segments:
                    if s.downstream == dn_id and s.data_node == dn_id:
                        used += 1
            self._sink_slots[dn_id] = max(0, dn.capacity - used)

    def remove_node(self, nid: int):
        """Crash: drop the node, unpair all segments that touched it."""
        p = self.protos.pop(nid, None)
        if p is None:
            return
        if nid not in self._data_set:
            for seg in p.segments:
                if seg.upstream is None:
                    self._index_discard(p, seg)
                self._memo_change.pop((nid, seg._order), None)
            self._memo_redirect.pop(nid, None)
            if p.stage >= 0:
                self._epoch[p.stage] += 1
                alive = self._stage_alive[p.stage]
                k = bisect_left(alive, nid)
                if k < len(alive) and alive[k] == nid:
                    del alive[k]
                if p.segments:
                    self._stage_with_segs[p.stage].remove(nid)
        self._broken.discard(nid)
        for other in self.protos.values():
            other.known_next.discard(nid)
            other.known_same.discard(nid)
            for s in other.segments:
                if s.downstream == nid:
                    self._set_downstream(other, s, None)  # re-pair later
                if s.upstream == nid:
                    self._set_upstream(other, s, None)    # unpaired outflow again
        # sink slots freed for flows that died with this node are reclaimed
        # lazily by the simulator between iterations.

    def add_node(self, node: Node):
        """Join: create protocol state with adjacent-stage views.

        Churn events are rare relative to rounds, so this mirrors the
        reference's O(N) membership walk; only the indexes and epochs
        need extra bookkeeping.
        """
        self._refresh_cost_source()
        S = self.net.num_stages
        p = ProtoNode(node.id, node.stage, node.capacity)
        if node.stage == S - 1:
            p.known_next = {m.id for m in self.net.data_nodes() if m.alive}
        else:
            p.known_next = {m.id for m in self.net.stage_nodes(node.stage + 1)}
        p.known_same = {m.id for m in self.net.stage_nodes(node.stage)} - {node.id}
        self.protos[node.id] = p
        if 0 <= node.stage:
            self._epoch[node.stage] += 1
            insort(self._stage_alive[node.stage], node.id)
        for other in self.protos.values():
            if other.node_id == node.id:
                continue
            on = self.net.nodes.get(other.node_id)
            if on is None:
                continue
            if on.stage == node.stage - 1 or (on.is_data and node.stage == 0):
                other.known_next.add(node.id)
            if on.stage == node.stage and not on.is_data:
                other.known_same.add(node.id)
            if on.is_data and node.stage == S - 1:
                p.known_next.add(on.id)
