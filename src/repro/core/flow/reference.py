"""Reference (unindexed) GWTF protocol — the equivalence oracle.

This is the seed's straightforward implementation of the decentralized
flow construction, kept verbatim except for two fixes shared with the
optimized engine:

* the ``step_round`` indentation bug — Request Change / Redirect used to
  run inside the data-node repair loop with a stale loop variable, so
  annealed refinement effectively never executed; here (and in the
  optimized engine) they run once per relay per round as the paper
  specifies (Sec. V-C);
* the refinement sampling uses a *batched per-round RNG discipline*: a
  round draws ``rng.shuffle`` for the node order and then ONE uniform
  block ``rng.random((len(order), 4))`` whose row ``k`` holds the four
  variates node ``order[k]`` may need this round — source polling
  rotation, refinement segment choice, and the Request Change / Request
  Redirect visit-order rotations (candidate lists are visited in sorted
  order starting at a random offset, ``int(u * n)``).  Unused slots are
  simply not read, so the stream position after a round is a pure
  function of the membership size — which is what lets the optimized
  engine vectorize whole scans without perturbing the stream.  The only
  draws made *inside* a scan are the annealed-acceptance uniforms, one
  per non-improving candidate visited while T > 1e-6, taken from the
  same stream in visit order (``numpy`` sized draws produce the
  identical sequence, so the optimized engine may draw them as one
  block).

Every query here is a linear scan (O(peers x segments) per round) and
``_refresh_costs`` is recursive — this is intentionally the *slow but
obviously correct* formulation.  ``GWTFProtocol`` in ``decentralized.py``
must produce byte-identical flows and an identical RNG stream for any
seed; ``tests/test_flow_scale.py`` asserts this and
``benchmarks/bench_scale.py`` uses this class as the pre-optimization
baseline.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.flow.decentralized import ProtoNode, Segment
from repro.core.flow.graph import FlowNetwork, Node


class ReferenceGWTFProtocol:
    """Round-based execution of the decentralized flow construction,
    with per-round linear scans instead of incremental indexes."""

    def __init__(self, net: FlowNetwork, *,
                 cost_matrix: Optional[np.ndarray] = None,
                 temperature: float = 1.7, alpha: float = 0.95,
                 objective: str = "minmax",
                 peer_view: Optional[int] = None,
                 refine: bool = True,
                 rng: Optional[np.random.Generator] = None):
        self.net = net
        self.cost_matrix = cost_matrix
        self.T = temperature
        self.alpha = alpha
        self.objective = objective
        self.refine = refine
        self.rng = rng or np.random.default_rng(0)
        self.peer_view = peer_view
        self._flow_counter = itertools.count()
        self.protos: Dict[int, ProtoNode] = {}
        self._sink_slots: Dict[int, int] = {}    # data node -> free sink slots
        self._build_protocol_state()

    # ------------------------------------------------------------------
    def d(self, i: int, j: int) -> float:
        if self.cost_matrix is not None:
            return float(self.cost_matrix[i, j])
        return self.net.edge_cost(i, j)

    def _build_protocol_state(self):
        S = self.net.num_stages
        for n in self.net.nodes.values():
            if not n.alive:
                continue
            p = ProtoNode(n.id, n.stage, n.capacity)
            self.protos[n.id] = p
        for p in self.protos.values():
            n = self.net.nodes[p.node_id]
            if n.is_data:
                self._sink_slots[n.id] = n.capacity
                nxt = {m.id for m in self.net.stage_nodes(0)}
            elif n.stage == S - 1:
                nxt = {m.id for m in self.net.data_nodes() if m.alive}
            else:
                nxt = {m.id for m in self.net.stage_nodes(n.stage + 1)}
            same = {m.id for m in self.net.stage_nodes(n.stage)} - {n.id}
            if self.peer_view is not None:
                nxt = set(self.rng.choice(sorted(nxt),
                                          size=min(self.peer_view, len(nxt)),
                                          replace=False)) if nxt else set()
            p.known_next = nxt
            p.known_same = same

    # ------------------------------------------------------------------
    # Queries (what a peer answers when asked — local information only)
    # ------------------------------------------------------------------
    def _advertised(self, j: int, data_node: int) -> Optional[float]:
        """Peer j's advertised cost-to-sink for an unpaired outflow to
        ``data_node``; None if it has none (infinite)."""
        pj = self.protos.get(j)
        if pj is None or not pj.alive:
            return None
        if self.net.nodes[j].is_data:
            # the sink itself: free slot -> cost 0
            return 0.0 if (j == data_node and self._sink_slots[j] > 0) else None
        best = None
        for s in pj.unpaired_outflows():
            if s.data_node == data_node:
                if best is None or s.cost_to_sink < best:
                    best = s.cost_to_sink
        return best

    # ------------------------------------------------------------------
    # Request Flow
    # ------------------------------------------------------------------
    def _request_flow(self, i: int, data_node: int) -> bool:
        """Node i tries to pair with a subsequent-stage unpaired outflow."""
        pi = self.protos[i]
        best_j, best_total, best_cts = None, None, None
        for j in pi.known_next:
            cts = self._advertised(j, data_node)
            if cts is None:
                continue
            total = cts + self.d(i, j)
            if best_total is None or total < best_total:
                best_j, best_total, best_cts = j, total, cts
        if best_j is None:
            return False
        # --- the Request Flow message exchange ---
        pj = self.protos.get(best_j)
        if self.net.nodes[best_j].is_data:
            if self._sink_slots[best_j] <= 0:
                return False
            self._sink_slots[best_j] -= 1
            fid = next(self._flow_counter)
            pi.segments.append(Segment(fid, data_node, best_j, None, self.d(i, best_j)))
            return True
        target = None
        for s in pj.unpaired_outflows():
            if s.data_node == data_node and abs(s.cost_to_sink - best_cts) < 1e-9:
                target = s
                break
        if target is None:      # stale cost -> reject (requester retries next round)
            return False
        target.upstream = i
        pi.segments.append(Segment(target.flow_id, data_node, best_j, None,
                                   target.cost_to_sink + self.d(i, best_j)))
        return True

    # ------------------------------------------------------------------
    # Request Change (same-stage peer swap, annealed)
    # ------------------------------------------------------------------
    def _request_change(self, i: int, u_seg: float, u_rot: float) -> bool:
        pi = self.protos[i]
        if not pi.segments:
            return False
        si = pi.segments[int(u_seg * len(pi.segments))]
        if si.downstream is None or self.net.nodes[si.downstream].is_data:
            return False
        candidates = sorted(j for j in pi.known_same
                            if j in self.protos and self.protos[j].alive)
        n = len(candidates)
        start = int(u_rot * n) if n else 0
        for k in range(n):
            t = start + k
            j = candidates[t if t < n else t - n]
            pj = self.protos[j]
            for sj in pj.segments:
                if (sj.data_node != si.data_node or sj.downstream is None
                        or self.net.nodes[sj.downstream].is_data
                        or sj.downstream == si.downstream):
                    continue
                if self.objective == "sum":
                    cur = self.d(i, si.downstream) + self.d(j, sj.downstream)
                    new = self.d(i, sj.downstream) + self.d(j, si.downstream)
                else:
                    cur = max(self.d(i, si.downstream), self.d(j, sj.downstream))
                    new = max(self.d(i, sj.downstream), self.d(j, si.downstream))
                if self._anneal_accept(cur, new):
                    # swap downstream peers; inform next-stage nodes
                    di, dj = si.downstream, sj.downstream
                    self._repoint_upstream(di, old_up=i, new_up=j,
                                           data_node=si.data_node)
                    self._repoint_upstream(dj, old_up=j, new_up=i,
                                           data_node=sj.data_node)
                    si.downstream, sj.downstream = dj, di
                    self._refresh_costs(i)
                    self._refresh_costs(j)
                    return True
        return False

    def _repoint_upstream(self, downstream_id: int, *, old_up: int,
                          new_up: Optional[int], data_node: int):
        pd = self.protos.get(downstream_id)
        if pd is None:
            return
        for s in pd.segments:
            if s.upstream == old_up and s.data_node == data_node:
                s.upstream = new_up
                return

    # ------------------------------------------------------------------
    # Request Redirect (node substitution, annealed)
    # ------------------------------------------------------------------
    def _request_redirect(self, m: int, u_rot: float) -> bool:
        """Spare node m offers to replace peer b on a chain a -> b -> c."""
        pm = self.protos[m]
        if pm.free <= 0:
            return False
        peers = sorted(j for j in pm.known_same
                       if j in self.protos and self.protos[j].alive
                       and self.protos[j].segments)
        n = len(peers)
        start = int(u_rot * n) if n else 0
        for k in range(n):
            t = start + k
            b = peers[t if t < n else t - n]
            pb = self.protos[b]
            for sb in pb.segments:
                if sb.upstream is None or sb.downstream is None:
                    continue
                a, c = sb.upstream, sb.downstream
                cur = self.d(a, b) + self.d(b, c)
                new = self.d(a, m) + self.d(m, c)
                if self._anneal_accept(cur, new):
                    # b approves: m takes over the segment
                    pb.segments.remove(sb)
                    seg = dataclasses.replace(
                        sb, cost_to_sink=sb.cost_to_sink
                        - self.d(b, c) + self.d(m, c))
                    pm.segments.append(seg)
                    # upstream a (may be the data node) and downstream c repoint
                    pa = self.protos.get(a)
                    if pa is not None:
                        for s in pa.segments:
                            if s.downstream == b and s.data_node == sb.data_node:
                                s.downstream = m
                                break
                    if not self.net.nodes[c].is_data:
                        self._repoint_upstream(c, old_up=b, new_up=m,
                                               data_node=sb.data_node)
                    self._refresh_costs(m)
                    return True
        return False

    def _anneal_accept(self, cur: float, new: float) -> bool:
        if new < cur:
            self.T *= self.alpha
            return True
        if self.T <= 1e-6:
            return False
        p = math.exp(min((cur - new) / self.T, 0.0))
        if p > self.rng.uniform(0.0, 1.0):
            self.T *= self.alpha
            return True
        return False

    def _refresh_costs(self, i: int):
        """Recompute cost_to_sink for node i and broadcast upstream.

        Level-order (stage-by-stage) propagation with two message-passing
        rules shared with the optimized engine: a node visited once per
        wave recomputes *all* its segments, and a cost update is
        forwarded to a segment's feeder only if recomputation *changed*
        that segment's value (a no-op advertisement is not sent).
        """
        level = [i]
        seen = {i}
        while level:
            nxt: List[int] = []
            for nid in level:
                pi = self.protos.get(nid)
                if pi is None:
                    continue
                for s in pi.segments:
                    if s.downstream is None:
                        continue
                    down_cost = 0.0
                    pd = self.protos.get(s.downstream)
                    if (pd is not None
                            and not self.net.nodes[s.downstream].is_data):
                        for sd in pd.segments:
                            if (sd.upstream == nid
                                    and sd.data_node == s.data_node):
                                down_cost = sd.cost_to_sink
                                break
                    val = down_cost + self.d(nid, s.downstream)
                    if val != s.cost_to_sink:
                        s.cost_to_sink = val
                        up = s.upstream
                        if (up is not None and up not in seen
                                and not self.net.nodes[up].is_data):
                            seen.add(up)
                            nxt.append(up)
            level = nxt

    # ------------------------------------------------------------------
    # Round driver
    # ------------------------------------------------------------------
    def step_round(self) -> int:
        """One synchronous protocol round; returns number of state changes."""
        changes = 0
        order = np.asarray(sorted(self.protos))
        self.rng.shuffle(order)
        # the round's RNG block: row k = (source rotation, segment choice,
        # change rotation, redirect rotation) for node order[k].  Drawn
        # unconditionally so the stream position is decision-independent.
        block = self.rng.random((len(order), 4))
        for k, i in enumerate(order.tolist()):
            pi = self.protos[i]
            if not pi.alive or self.net.nodes[i].is_data:
                continue
            if pi.free > 0 and pi.stable():
                for dn in self._known_data_nodes(i, block[k, 0]):
                    if pi.free <= 0:
                        break
                    if self._request_flow(i, dn):
                        changes += 1
            # nodes with unpaired inflow (downstream lost) re-pair downstream
            for s in list(pi.segments):
                if s.downstream is None:
                    if self._repair_downstream(i, s):
                        s._deny_after = 3
                        changes += 1
                    else:
                        # DENY (Sec. V-D): if no alternate peer exists after
                        # a few attempts, release the segment and tell the
                        # upstream so the flow can be redistributed.
                        s._deny_after = getattr(s, "_deny_after", 3) - 1
                        if s._deny_after <= 0:
                            self._deny(i, s)
                            changes += 1
            # annealed refinement runs for every relay, every round
            # (paper Sec. V-C)
            if self.refine:
                if self._request_change(i, block[k, 1], block[k, 2]):
                    changes += 1
                if self._request_redirect(i, block[k, 3]):
                    changes += 1
        # data nodes also repair source-side segments whose downstream died
        for dn in self.net.data_nodes():
            pd = self.protos.get(dn.id)
            if pd is None:
                continue
            for s in list(pd.segments):
                if s.downstream is None:
                    pd.segments.remove(s)       # re-issue via _connect_sources
                    changes += 1
        # data nodes (source side) connect to stage-0 unpaired outflows
        changes += self._connect_sources()
        return changes

    def _known_data_nodes(self, i: int, u_rot: float) -> List[int]:
        # rotation from a random offset: avoids fixed-priority source
        # bias without a per-node shuffle draw
        dns = [n.id for n in self.net.data_nodes() if n.alive]
        if len(dns) > 1:
            r = int(u_rot * len(dns))
            dns = dns[r:] + dns[:r]
        return dns

    def _repair_downstream(self, i: int, seg: Segment) -> bool:
        """Re-pair a segment whose downstream crashed (unpaired inflow)."""
        pi = self.protos[i]
        best_j, best_total, best_cts = None, None, None
        for j in pi.known_next:
            cts = self._advertised(j, seg.data_node)
            if cts is None:
                continue
            total = cts + self.d(i, j)
            if best_total is None or total < best_total:
                best_j, best_total, best_cts = j, total, cts
        if best_j is None:
            return False
        if self.net.nodes[best_j].is_data:
            if self._sink_slots[best_j] <= 0:
                return False
            self._sink_slots[best_j] -= 1
            seg.downstream = best_j
            seg.cost_to_sink = self.d(i, best_j)
            return True
        pj = self.protos[best_j]
        for s in pj.unpaired_outflows():
            if s.data_node == seg.data_node and abs(s.cost_to_sink - best_cts) < 1e-9:
                s.upstream = i
                seg.downstream = best_j
                seg.cost_to_sink = s.cost_to_sink + self.d(i, best_j)
                return True
        return False

    def _deny(self, i: int, seg: Segment):
        """Drop an unrepairable segment and unpair its upstream feeder."""
        pi = self.protos.get(i)
        if pi is None or seg not in pi.segments:
            return
        up = seg.upstream
        pi.segments.remove(seg)
        if up is None:
            return
        pu = self.protos.get(up)
        if pu is None:
            return
        if self.net.nodes[up].is_data:
            # the source drops its segment and re-issues via connect_sources
            for su in list(pu.segments):
                if su.downstream == i and su.data_node == seg.data_node:
                    pu.segments.remove(su)
                    break
        else:
            for su in pu.segments:
                if su.downstream == i and su.data_node == seg.data_node:
                    su.downstream = None
                    break

    def _connect_sources(self) -> int:
        """Source side of each data node pairs with stage-0 unpaired outflows."""
        changes = 0
        for dn in self.net.data_nodes():
            if not dn.alive:
                continue
            pd = self.protos[dn.id]
            while pd.used < pd.capacity:
                best = None
                for j in pd.known_next:
                    pj = self.protos.get(j)
                    if pj is None or not pj.alive:
                        continue
                    for s in pj.unpaired_outflows():
                        if s.data_node == dn.id:
                            total = s.cost_to_sink + self.d(dn.id, j)
                            if best is None or total < best[0]:
                                best = (total, j, s)
                if best is None:
                    break
                _, j, s = best
                s.upstream = dn.id
                pd.segments.append(Segment(s.flow_id, dn.id, j, None,
                                           best[0]))
                changes += 1
        return changes

    def run(self, max_rounds: int = 200, quiet_rounds: int = 25) -> int:
        quiet = 0
        r = 0
        for r in range(max_rounds):
            if self.step_round() == 0:
                quiet += 1
                if quiet >= quiet_rounds:
                    break
            else:
                quiet = 0
        return r + 1

    # ------------------------------------------------------------------
    # Results
    # ------------------------------------------------------------------
    def complete_flows(self) -> List[List[int]]:
        """Chains data_node -> s0 -> ... -> s(S-1) -> data_node."""
        chains = []
        visited = set()
        for dn in self.net.data_nodes():
            pd = self.protos.get(dn.id)
            if pd is None:
                continue
            for seg in pd.segments:
                chain = [dn.id]
                prev, cur = dn.id, seg.downstream
                ok = True
                for _ in range(self.net.num_stages + 1):
                    if cur is None:
                        ok = False
                        break
                    chain.append(cur)
                    if cur == dn.id:
                        break
                    pc = self.protos.get(cur)
                    nxt = None
                    if pc is not None:
                        for s in pc.segments:
                            if (id(s) not in visited and s.upstream == prev
                                    and s.data_node == dn.id):
                                nxt = s.downstream
                                visited.add(id(s))
                                break
                    prev, cur = cur, nxt
                if ok and chain[-1] == dn.id and len(chain) == self.net.num_stages + 2:
                    chains.append(chain)
        return chains

    def flow_costs(self) -> List[float]:
        costs = []
        for chain in self.complete_flows():
            c = sum(self.d(chain[k], chain[k + 1]) for k in range(len(chain) - 1))
            costs.append(c)
        return costs

    def total_cost(self) -> float:
        return float(sum(self.flow_costs()))

    def max_edge_cost(self) -> float:
        m = 0.0
        for chain in self.complete_flows():
            for k in range(len(chain) - 1):
                m = max(m, self.d(chain[k], chain[k + 1]))
        return m

    # ------------------------------------------------------------------
    # Churn hooks (used by the simulator)
    # ------------------------------------------------------------------
    def reclaim_sink_slots(self):
        """Recount free sink slots + garbage-collect stale segments."""
        self._gc_pass = getattr(self, "_gc_pass", 0) + 1
        for p in self.protos.values():
            node = self.net.nodes.get(p.node_id)
            if node is None or node.is_data:
                continue
            for s in list(p.segments):
                unpaired = s.upstream is None or s.downstream is None
                last = getattr(s, "_stale_since", None)
                if unpaired:
                    if last is None:
                        s._stale_since = self._gc_pass
                    elif self._gc_pass - last >= 2:
                        # free the memory; downstream/upstream unpair too
                        if s.downstream is not None:
                            self._repoint_upstream(s.downstream, old_up=p.node_id,
                                                   new_up=None,
                                                   data_node=s.data_node)
                        if s.upstream is not None:
                            pu = self.protos.get(s.upstream)
                            if pu is not None:
                                for su in pu.segments:
                                    if (su.downstream == p.node_id
                                            and su.data_node == s.data_node):
                                        su.downstream = None
                                        break
                        p.segments.remove(s)
                else:
                    s._stale_since = None
        for dn in self.net.data_nodes():
            used = 0
            for p in self.protos.values():
                node = self.net.nodes.get(p.node_id)
                if node is None or node.is_data:
                    continue
                for s in p.segments:
                    if s.downstream == dn.id and s.data_node == dn.id:
                        used += 1
            self._sink_slots[dn.id] = max(0, dn.capacity - used)

    def remove_node(self, nid: int):
        """Crash: drop the node, unpair all segments that touched it."""
        p = self.protos.pop(nid, None)
        if p is None:
            return
        for other in self.protos.values():
            other.known_next.discard(nid)
            other.known_same.discard(nid)
            for s in other.segments:
                if s.downstream == nid:
                    s.downstream = None          # unpaired inflow: re-pair later
                if s.upstream == nid:
                    s.upstream = None            # unpaired outflow again
        # sink slots freed for flows that died with this node are reclaimed
        # lazily by the simulator between iterations.

    def add_node(self, node: Node):
        """Join: create protocol state with adjacent-stage views."""
        S = self.net.num_stages
        p = ProtoNode(node.id, node.stage, node.capacity)
        if node.stage == S - 1:
            p.known_next = {m.id for m in self.net.data_nodes() if m.alive}
        else:
            p.known_next = {m.id for m in self.net.stage_nodes(node.stage + 1)}
        p.known_same = {m.id for m in self.net.stage_nodes(node.stage)} - {node.id}
        self.protos[node.id] = p
        for other in self.protos.values():
            if other.node_id == node.id:
                continue
            on = self.net.nodes.get(other.node_id)
            if on is None:
                continue
            if on.stage == node.stage - 1 or (on.is_data and node.stage == 0):
                other.known_next.add(node.id)
            if on.stage == node.stage and not on.is_data:
                other.known_same.add(node.id)
            if on.is_data and node.stage == S - 1:
                p.known_next.add(on.id)
