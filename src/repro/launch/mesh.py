"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).

Single pod : (16, 16)    axes (data, model)  = 256 chips
Multi-pod  : (2, 16, 16) axes (pod, data, model) = 512 chips
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / smoke runs)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link
