"""Scan-aware HLO cost analysis for the roofline report.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
which under-reports FLOPs and collective bytes by ~num_layers for scanned
models.  This module parses the compiled HLO text, recovers while-loop
trip counts from their condition computations, and propagates execution
multipliers through the call graph (body= / condition= / calls= /
to_apply=), yielding:

* ``dot_flops``          — 2 * prod(result_dims) * contraction, x trips
* ``collective_bytes``   — per collective type (result-shape bytes), x trips
* ``collective_count``

These feed EXPERIMENTS.md §Roofline.  Parsing is defensive: anything that
fails to parse contributes at multiplier 1 (never silently dropped).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "f64": 8, "s64": 8, "pred": 1, "s16": 2, "u16": 2,
          "c64": 8, "c128": 16, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_RE = re.compile(
    r"\b(" + "|".join(COLLECTIVES) + r")(-start)?\(")


def _dims_of(shape_str: str) -> List[Tuple[str, List[int]]]:
    """All (dtype, dims) annotations in a string."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _elems(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class _Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    symbols: Dict[str, Tuple[str, List[int]]] = field(default_factory=dict)


def _split_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        stripped = raw.strip()
        if cur is None or not raw.startswith(" "):
            hdr = _COMP_HDR.match(stripped)
            if hdr and stripped.endswith("{"):
                cur = _Computation(hdr.group(1))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        if not stripped:
            continue
        cur.lines.append(stripped)
        d = _DEF_RE.match(stripped)
        if d:
            shapes = _dims_of(d.group(2).split("(")[0])
            if shapes:
                cur.symbols[d.group(1)] = shapes[0]
    return comps


def _find_entry(text: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    return m.group(1) if m else None


def _trip_count(cond: _Computation) -> int:
    """while-condition: compare(iter, constant(N)) direction=LT -> N."""
    consts = [int(m.group(1)) for line in cond.lines
              for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


@dataclass
class HLOCosts:
    dot_flops: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    collective_count: float = 0.0
    while_loops: int = 0
    unparsed_dots: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops_line(line: str, symbols) -> Tuple[float, bool]:
    m = re.search(r"=\s+(.*?)\s*dot\(([^)]*)\)", line)
    if not m:
        return 0.0, False
    res = _dims_of(m.group(1))
    if not res:
        return 0.0, False
    res_elems = _elems(res[0][1])
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    # Scheduled HLO annotates operands inline (`dot(f32[2,16] %a, ...)`);
    # unscheduled HLO gives bare names -> fall back to the symbol table.
    inline = _dims_of(m.group(2))
    if inline:
        lhs = inline[0]
    else:
        ops = [o.strip().lstrip("%") for o in m.group(2).split(",")]
        lhs = symbols.get(ops[0]) if ops else None
    if cm is not None and lhs is not None:
        cdims = [int(x) for x in cm.group(1).split(",") if x]
        k = 1
        for d in cdims:
            if d < len(lhs[1]):
                k *= lhs[1][d]
        return 2.0 * res_elems * k, True
    return 0.0, True        # dot seen but contraction unknown


def analyze_hlo(text: str) -> HLOCosts:
    comps = _split_computations(text)
    entry = _find_entry(text)
    costs = HLOCosts()
    if entry is None or entry not in comps:
        return costs

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop(0)
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        for line in comp.lines:
            if " while(" in line or line.startswith("while("):
                costs.while_loops += 1
                body = re.search(r"body=%?([\w\.\-]+)", line)
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                trip = 1
                if cond and cond.group(1) in comps:
                    trip = _trip_count(comps[cond.group(1)])
                if body:
                    b = body.group(1)
                    mult[b] += m * trip
                    if b not in seen:
                        seen.add(b)
                        order.append(b)
                continue
            for _, target in re.findall(r"(calls|to_apply)=%?([\w\.\-]+)",
                                        line):
                mult[target] += m
                if target not in seen:
                    seen.add(target)
                    order.append(target)
            cm = re.search(r"(?:conditional|case)\(", line)
            if cm:
                for t in re.findall(r"branch_computations=\{([^}]*)\}", line):
                    for target in t.replace("%", "").split(","):
                        target = target.strip()
                        mult[target] += m
                        if target and target not in seen:
                            seen.add(target)
                            order.append(target)

    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in comp.lines:
            if "dot(" in line:
                f, ok = _dot_flops_line(line, comp.symbols)
                costs.dot_flops += m * f
                if not ok:
                    costs.unparsed_dots += 1
                continue
            cm = _COLL_RE.search(line)
            if cm and "-done(" not in line:
                mres = re.search(r"=\s+(.*?)\s*" + cm.group(1), line)
                b = 0
                if mres:
                    for dt, dims in _dims_of(mres.group(1)):
                        b += _elems(dims) * _BYTES[dt]
                # fallback: whole-line first shape
                if b == 0:
                    shapes = _dims_of(line)
                    if shapes:
                        b = _elems(shapes[0][1]) * _BYTES[shapes[0][0]]
                costs.collective_bytes[cm.group(1)] += m * b
                costs.collective_count += m
    return costs


_CONVERT_RE = re.compile(
    r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*f32\[([0-9,]+)\]\S*\s+"
    r"(?:convert\(|fusion\((?=[^)]*\)[^\n]*calls=%?wrapped_convert))")


def f32_legalization_bytes(text: str, min_bytes: int = 100_000_000) -> float:
    """Bytes of large f32 buffers produced by bf16->f32 converts.

    XLA:CPU has no native bf16 GEMM: it legalises by converting operands
    to f32, and LICM hoists loop-invariant converts into full-tensor f32
    copies (e.g. an entire KV-cache stack).  On TPU the MXU consumes bf16
    directly, so these buffers do not exist.  Each buffer is counted once
    (memory, not executions).  Used to derive ``tpu_temp_estimate`` in the
    dry-run records; see EXPERIMENTS.md §Dry-run notes.
    """
    total = 0.0
    seen = set()
    in_wrapped_convert = False
    for raw in text.splitlines():
        ls = raw.strip()
        if not raw.startswith(" ") and ls.endswith("{"):
            in_wrapped_convert = "wrapped_convert_computation" in ls
            continue
        if in_wrapped_convert:
            continue          # inner body duplicates the fusion result
        m = _CONVERT_RE.match(ls)
        if not m:
            continue
        name, dims = m.group(1), m.group(2)
        if name in seen or "convert(%convert" in ls:
            continue          # chained converts share a transient buffer
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * 4
        if b >= min_bytes:
            seen.add(name)
            total += b
    return total
