"""End-to-end training driver.

Two modes:

* ``--mode spmd``  — single-program pjit training on the local device mesh
  (the path the production meshes would run; on CPU it uses the host
  devices).  Reduced configs train for real here.
* ``--mode gwtf``  — the paper's decentralized training: a FlowNetwork of
  data/relay nodes, GWTF flow routing, churn, and per-stage replicas via
  :class:`repro.core.executor.DecentralizedTrainer`.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gwtf-llama-300m \
      --mode gwtf --stages 4 --iterations 50 --churn 0.1
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --mode spmd --reduced --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def run_spmd(args):
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import store
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, DataNodeShard
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_params
    from repro.optim.adamw import AdamW
    from repro.parallel.sharding import ShardingRules

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=args.layers, d_model=args.d_model)
    mesh = make_host_mesh()
    rules = ShardingRules()
    opt = AdamW(lr=args.lr)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, mesh=mesh, rules=rules))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    batch_size=args.batch, microbatch_size=args.batch,
                    seed=args.seed)
    shard = DataNodeShard(dc, 0, 1)
    with mesh:
        for step in range(args.steps):
            b = shard.next_batch()
            batch = {"tokens": jnp.asarray(b["tokens"]),
                     "labels": jnp.asarray(b["labels"])}
            t0 = time.time()
            params, opt_state, loss = step_fn(params, opt_state, batch)
            if step % args.log_every == 0:
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.2f}s)")
    if args.checkpoint:
        store.save(args.checkpoint, params, step=args.steps)
        print("checkpoint ->", args.checkpoint)
    print(f"final loss {float(loss):.4f}")
    return float(loss)


def run_gwtf(args):
    from repro.configs import get_config
    from repro.core.executor import DecentralizedTrainer
    from repro.core.flow.graph import geo_distributed_network
    from repro.data.pipeline import DataConfig, DataNodeShard

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=max(args.stages, args.layers),
                          d_model=args.d_model)
    rng = np.random.default_rng(args.seed)
    caps = [args.capacity] * (args.stages * args.relays_per_stage)
    net = geo_distributed_network(
        num_stages=args.stages, relay_capacities=caps,
        num_data_nodes=args.data_nodes, data_capacity=args.microbatches,
        rng=rng)
    trainer = DecentralizedTrainer(cfg, net, churn=args.churn, lr=args.lr,
                                   seed=args.seed)
    shards = {d.id: DataNodeShard(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                   batch_size=args.microbatches * args.batch,
                   microbatch_size=args.batch, seed=args.seed + d.id),
        d.id, args.data_nodes) for d in net.data_nodes()}
    for it in range(args.iterations):
        batches = {dn: shards[dn].microbatches() for dn in shards}
        r = trainer.iteration(batches)
        print(f"iter {it:4d} loss {r.loss:.4f} "
              f"completed {r.completed}/{r.launched} dropped {r.dropped}")
    print(f"final loss {trainer.losses[-1]:.4f}")
    return trainer.losses[-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gwtf-llama-300m")
    ap.add_argument("--mode", choices=("spmd", "gwtf"), default="gwtf")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--iterations", type=int, default=20)
    ap.add_argument("--stages", type=int, default=4)
    ap.add_argument("--relays-per-stage", type=int, default=3)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--data-nodes", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--churn", type=float, default=0.0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()
    if args.mode == "spmd":
        run_spmd(args)
    else:
        run_gwtf(args)


if __name__ == "__main__":
    main()
