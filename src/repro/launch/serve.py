"""Serving driver: batched prefill + decode with a KV cache.

Complements launch/train.py — the decode_32k / long_500k dry-run shapes
lower exactly this step.  On CPU it serves a reduced config for real:

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 32 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --reduced --long    # sliding-window/SSM-state long-context mode
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--long", action="store_true",
                    help="sliding-window ring-buffer mode (long_500k path)")
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route prefill attention through the Pallas kernel")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.transformer import decode_step, init_cache, prefill

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=args.layers, d_model=args.d_model)
    window = args.window if args.long else None
    cache_len = window if args.long else args.prompt_len + args.gen

    # independent keys per purpose (params / prompt / aux / sampling) —
    # the shared split with the flow-routed serving runtime, so its
    # zero-churn decode is bit-comparable to this driver on one seed
    from repro.core.runtime.serving import serving_inputs

    B = args.batch
    params, prompt, vision, embeds, k_sample = serving_inputs(
        cfg, seed=args.seed, batch=B, prompt_len=args.prompt_len)

    cache = init_cache(cfg, B, cache_len, dtype=jnp.float32)
    t0 = time.time()
    if cfg.audio_frontend:
        logits, cache = prefill(params, cfg, embeds=embeds, cache=cache)
    else:
        logits, cache = prefill(params, cfg, tokens=prompt, vision=vision,
                                cache=cache)
    print(f"prefill: bs={B} len={args.prompt_len} "
          f"({time.time()-t0:.2f}s incl. compile)")

    step = jax.jit(lambda p, tok, c, i: decode_step(
        p, cfg, tokens=tok, vision=vision, cache=c, index=i, window=window))

    def sample(logits, k):
        if args.temperature <= 0:
            return jnp.argmax(logits, -1)[:, None]
        return jax.random.categorical(
            k, logits / args.temperature)[:, None]

    k_sample, k0 = jax.random.split(k_sample)
    tok = sample(logits, k0)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen):
        k_sample, sk = jax.random.split(k_sample)
        logits, cache = step(params, tok, cache,
                             jnp.int32(args.prompt_len + i))
        tok = sample(logits, sk)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen} steps x {B} seqs in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s{' , ring-buffer' if args.long else ''})")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
