"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST run before any jax import (jax locks the device
count at first init); 512 host devices back the production meshes:

  * single-pod (16, 16)   ("data", "model")          = 256 chips
  * multi-pod  (2, 16, 16) ("pod", "data", "model")  = 512 chips

For each combination this lowers the appropriate step (train_4k ->
train_step, prefill_32k -> prefill_step, decode_32k / long_500k ->
serve_step), compiles it, and records memory_analysis / cost_analysis /
collective byte counts parsed from the compiled HLO — the inputs to
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod|--both-meshes]
"""
from __future__ import annotations

# The env var MUST be set before any jax import — jax locks the device
# count at first init.  These are the required "first two lines" modulo
# the module docstring (a string literal cannot execute after imports).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import (COLLECTIVES, analyze_hlo,
                                       f32_legalization_bytes)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_params, decode_cache_len,
                                input_specs)
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step, serve_shardings,
                                train_shardings)
from repro.models.config import INPUT_SHAPES
from repro.optim.adamw import AdamW
from repro.parallel.sharding import ShardingRules


def analytic_memory(cfg, shape, *, chips: int, grad_accum: int) -> Dict[str, float]:
    """Model-based per-chip TPU memory estimate (bytes).

    The compile-side memory_analysis() on the CPU backend includes
    bf16->f32 legalization copies that do not exist on the TPU MXU; this
    analytic model is the TPU-side "fits" evidence (cross-checked against
    the measured temp minus the detected legalization buffers).
    """
    n_params = cfg.param_count()
    out: Dict[str, float] = {}
    if shape.kind == "train":
        micro_rows = max(1, shape.global_batch // grad_accum // 16)
        act = micro_rows * shape.seq_len * cfg.d_model * 2
        layers_live = cfg.num_layers          # remat carry, seq/16 sharded
        out["params"] = n_params * 2 / chips
        out["optimizer"] = n_params * 8 / chips
        out["grad_accum_f32"] = n_params * 4 / chips
        out["activations"] = act * layers_live / 16      # seq-parallel
        out["workspace"] = 2e9
    elif shape.kind == "prefill":
        rows = max(1, shape.global_batch // 16)
        out["params"] = n_params * 2 / chips * 16        # TP-sharded only
        cache = (2 * cfg.num_layers * shape.global_batch * shape.seq_len
                 * cfg.kv_dim * 2) if cfg.num_heads else 0
        out["kv_cache"] = cache / chips
        out["activations"] = rows * shape.seq_len * cfg.d_model * 2 * 4 / 16
        out["workspace"] = 1e9
    else:
        from repro.launch.specs import decode_cache_len
        clen = decode_cache_len(cfg, shape)
        cache = (2 * cfg.num_layers * shape.global_batch * clen
                 * cfg.kv_dim * 2) if cfg.num_heads else 0
        if cfg.has_ssm:
            di = cfg.d_inner
            cache += (cfg.num_layers * shape.global_batch
                      * (cfg.ssm_heads * (di // max(1, cfg.ssm_heads))
                         * cfg.ssm_state * 4 + (cfg.ssm_conv - 1)
                         * (di + 2 * cfg.ssm_state) * 2))
        out["params"] = n_params * 2 / chips * 16
        out["kv_cache"] = cache / chips                  # donated in place
        out["workspace"] = 1e9
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# Lower + compile one combination
# ---------------------------------------------------------------------------

DEFAULT_GRAD_ACCUM = 8


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            moe_impl: str = "dense", grad_accum: Optional[int] = None,
            infer_params: str = "fsdp",
            rules: Optional[ShardingRules] = None,
            verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if rules is None:
        # sequence-parallel residual stream for train/prefill (S >= 4096);
        # decode steps have S == 1 (the seq rule no-ops there anyway).
        rules = ShardingRules(seq="model" if shape.kind != "decode" else None)
    if infer_params == "replicated" and shape.kind != "train":
        # weight-stationary inference: params TP-sharded only (no FSDP),
        # eliminating per-layer weight all-gathers at serving time.
        rules = ShardingRules(seq=rules.seq, fsdp=None)
    t0 = time.time()
    if grad_accum is None:
        if shape.kind != "train":
            grad_accum = 1
        else:
            # keep per-device microbatch rows x d_model bounded, but the
            # per-microstep batch must stay divisible by the DP degree
            # (pod x data) or GSPMD silently replicates the batch.
            dp = 32 if multi_pod else 16
            grad_accum = DEFAULT_GRAD_ACCUM
            if cfg.d_model >= 8192 or cfg.is_moe:
                grad_accum = 16
            grad_accum = min(grad_accum, shape.global_batch // dp)

    params_abs = abstract_params(cfg)
    batch_abs = input_specs(cfg, shape_name, grad_accum=grad_accum)

    donate = ()
    if shape.kind == "train":
        opt = AdamW()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        in_sh, out_sh = train_shardings(cfg, params_abs, opt_abs, batch_abs,
                                        rules, mesh, grad_accum=grad_accum)
        step = make_train_step(cfg, opt, mesh=mesh, rules=rules,
                               moe_impl=moe_impl, grad_accum=grad_accum)
        args = (params_abs, opt_abs, batch_abs)
        donate = (0, 1)          # params + optimizer state are updated in place
    elif shape.kind == "prefill":
        cache_len = shape.seq_len
        cache_abs = jax.eval_shape(
            lambda: __import__("repro.models.transformer",
                               fromlist=["init_cache"]).init_cache(
                                   cfg, shape.global_batch, cache_len))
        in_sh, out_sh = serve_shardings(cfg, params_abs, batch_abs, rules,
                                        mesh, global_batch=shape.global_batch,
                                        cache_abstract=cache_abs)
        step = make_prefill_step(cfg, cache_len, mesh=mesh, rules=rules,
                                 moe_impl=moe_impl)
        args = (params_abs, batch_abs)
    else:
        window = (cfg.sliding_window
                  if decode_cache_len(cfg, shape) != shape.seq_len else None)
        in_sh, out_sh = serve_shardings(cfg, params_abs, batch_abs, rules,
                                        mesh, global_batch=shape.global_batch)
        step = make_decode_step(cfg, window=window, mesh=mesh, rules=rules,
                                moe_impl=moe_impl)
        args = (params_abs, batch_abs)
        donate = (1,)            # the KV cache is updated in place

    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    hlo = analyze_hlo(hlo_text)
    f32_legal = f32_legalization_bytes(hlo_text)
    elapsed = time.time() - t0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "moe_impl": moe_impl,
        "grad_accum": grad_accum,
        "infer_params": infer_params,
        "compile_s": round(elapsed, 1),
        "xla_flops_raw": cost.get("flops", 0.0),   # scan bodies counted once
        "dot_flops": hlo.dot_flops,                # scan-aware (per device)
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": hlo.total_collective_bytes,
        "collective_detail": dict(hlo.collective_bytes),
        "collective_count": hlo.collective_count,
        "while_loops": hlo.while_loops,
        "unparsed_dots": hlo.unparsed_dots,
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", 0),
            "output_size": getattr(mem, "output_size_in_bytes", 0),
            "temp_size": getattr(mem, "temp_size_in_bytes", 0),
            "alias_size": getattr(mem, "alias_size_in_bytes", 0),
            # XLA:CPU legalises bf16 GEMMs via f32 converts (often
            # loop-hoisted into full-tensor copies); the TPU MXU consumes
            # bf16 natively, so those buffers vanish there.
            "f32_legalization": f32_legal,
            "tpu_temp_estimate": max(
                0, getattr(mem, "temp_size_in_bytes", 0) - f32_legal),
            "peak": (getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "analytic_memory": analytic_memory(
            cfg, shape, chips=512 if multi_pod else 256,
            grad_accum=grad_accum),
    }
    if verbose:
        chips = 512 if multi_pod else 256
        print(f"[{arch} x {shape_name} x {result['mesh']}] "
              f"compile={elapsed:.1f}s dot_flops={result['dot_flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e} "
              f"coll={result['collective_bytes']:.3e} "
              f"temp/device={result['memory']['temp_size']/1e9:.2f}GB "
              f"(tpu-est {result['memory']['tpu_temp_estimate']/1e9:.2f}GB, "
              f"analytic {result['analytic_memory']['total']/1e9:.2f}GB) "
              f"args/device={result['memory']['argument_size']/1e9:.2f}GB")
        print(f"  memory_analysis: {mem}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-impl", default="dense",
                    choices=("dense", "ragged", "capacity"))
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--infer-params", default="fsdp",
                    choices=("fsdp", "replicated"))
    ap.add_argument("--out", default="experiments/dryrun_results.json")
    args = ap.parse_args()

    assigned = [a for a in ARCH_IDS if not a.startswith("gwtf_")]
    archs = [args.arch] if args.arch else assigned
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_one(arch, shape, multi_pod=mp,
                                           moe_impl=args.moe_impl,
                                           grad_accum=args.grad_accum,
                                           infer_params=args.infer_params))
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    failures.append((arch, shape, mp, repr(e)))
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    mode = "a" if os.path.exists(args.out) else "w"
    existing = []
    if mode == "a":
        try:
            existing = json.load(open(args.out))
        except Exception:
            existing = []
    keyset = {(r["arch"], r["shape"], r["mesh"], r["moe_impl"],
               r.get("infer_params", "fsdp"))
              for r in results}
    existing = [r for r in existing
                if (r["arch"], r["shape"], r["mesh"],
                    r.get("moe_impl", "dense"), r.get("infer_params", "fsdp"))
                not in keyset]
    json.dump(existing + results, open(args.out, "w"), indent=1)
    print(f"\n{len(results)} OK, {len(failures)} failed -> {args.out}")
    for f in failures:
        print("FAIL:", f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
