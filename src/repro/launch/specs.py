"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) pair.

No device allocation: the dry-run lowers against these abstract values.
The audio/VLM modality frontends are stubs — ``input_specs`` supplies the
precomputed frame/patch embeddings the decoder consumes (the one carve-out
to "no stubs"; see DESIGN.md Sec. 4).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import INPUT_SHAPES, InputShape, ModelConfig
from repro.models.transformer import init_cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: InputShape,
                      grad_accum: int = 1) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    lead: Tuple[int, ...] = ()
    if grad_accum > 1:
        assert B % grad_accum == 0, (B, grad_accum)
        lead, B = (grad_accum,), B // grad_accum
    batch: Dict[str, Any] = {"labels": sds(lead + (B, S), "int32")}
    if cfg.audio_frontend:
        batch["embeds"] = sds(lead + (B, S, cfg.d_model), "bfloat16")
    else:
        batch["tokens"] = sds(lead + (B, S), "int32")
    if cfg.arch_type == "vlm":
        batch["vision"] = sds(lead + (B, cfg.num_image_tokens, cfg.vision_dim),
                              "bfloat16")
    return batch


def prefill_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.audio_frontend:
        batch["embeds"] = sds((B, S, cfg.d_model), "bfloat16")
    else:
        batch["tokens"] = sds((B, S), "int32")
    if cfg.arch_type == "vlm":
        batch["vision"] = sds((B, cfg.num_image_tokens, cfg.vision_dim),
                              "bfloat16")
    return batch


def decode_cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    """long_500k uses the sliding-window ring buffer (sub-quadratic)."""
    if shape.seq_len > 65536 and cfg.sliding_window:
        return cfg.sliding_window
    return shape.seq_len


def pad_kv_heads(cfg: ModelConfig, tp: int = 16) -> int:
    """Decode-cache head padding (hillclimb D): when kvH does not divide
    the model axis, the flattened kv_dim sharding splits head_dim and XLA
    all-gathers the whole per-layer cache (~GBs/step).  Padding kvH up to
    the next multiple of tp gives fully local per-head attention.  Only
    worth it when the memory overhead is small (<= 1.7x): kvH 20 -> 32
    (qwen1.5), 24 -> 32 (musicgen).  Returns 0 for "no padding"."""
    if not cfg.has_attention or cfg.num_kv_heads % tp == 0:
        return 0
    padded = ((cfg.num_kv_heads + tp - 1) // tp) * tp
    if padded / cfg.num_kv_heads <= 1.7:
        return padded
    return 0


def decode_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B = shape.global_batch
    cache_len = decode_cache_len(cfg, shape)
    pad = pad_kv_heads(cfg)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, cache_len, dtype=jnp.bfloat16,
                           kv_heads_override=pad or None))
    batch: Dict[str, Any] = {"cache": cache,
                             "index": sds((), "int32")}
    if cfg.audio_frontend:
        batch["tokens"] = sds((B, 1), "int32")   # decode feeds back tokens
    else:
        batch["tokens"] = sds((B, 1), "int32")
    if cfg.arch_type == "vlm":
        batch["vision"] = sds((B, cfg.num_image_tokens, cfg.vision_dim),
                              "bfloat16")
    return batch


def input_specs(cfg: ModelConfig, shape_name: str,
                grad_accum: int = 1) -> Dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_specs(cfg, shape, grad_accum)
    if shape.kind == "prefill":
        return prefill_specs(cfg, shape)
    return decode_specs(cfg, shape)


def abstract_params(cfg: ModelConfig):
    from repro.models.transformer import init_params
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
