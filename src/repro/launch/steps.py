"""pjit-able train / prefill / decode steps + their sharding specs."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import INPUT_SHAPES, ModelConfig
from repro.models.transformer import (decode_step, prefill, train_loss)
from repro.optim.adamw import AdamW, AdamWState
from repro.parallel.sharding import ShardingRules, param_spec_tree, use_rules


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt: Optional[AdamW] = None,
                    mesh=None, rules: Optional[ShardingRules] = None,
                    moe_impl: str = "dense", grad_accum: int = 1):
    """grad_accum > 1: batch leaves carry a leading (grad_accum,) dim —
    microbatches are scanned with an f32 gradient accumulator (the paper's
    microbatch model applied on-chip), bounding live activation memory."""
    opt = opt or AdamW()

    def train_step(params, opt_state, batch):
        with use_rules(rules, mesh):
            if grad_accum > 1:
                def micro(carry, mb):
                    gacc, lacc = carry
                    loss, g = jax.value_and_grad(train_loss)(
                        params, mb, cfg, moe_impl=moe_impl)
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), gacc, g)
                    return (gacc, lacc + loss), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
                (gsum, lsum), _ = jax.lax.scan(
                    micro, (g0, jnp.float32(0.0)), batch)
                grads = jax.tree.map(lambda g: g / grad_accum, gsum)
                loss = lsum / grad_accum
            else:
                loss, grads = jax.value_and_grad(train_loss)(
                    params, batch, cfg, moe_impl=moe_impl)
            new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int, mesh=None,
                      rules: Optional[ShardingRules] = None,
                      moe_impl: str = "dense"):
    from repro.models.transformer import init_cache

    def prefill_step(params, batch):
        with use_rules(rules, mesh):
            cache = init_cache(cfg, next(iter(batch.values())).shape[0],
                               cache_len)
            logits, new_cache = prefill(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), vision=batch.get("vision"),
                cache=cache, moe_impl=moe_impl)
        return logits, new_cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, window=None, mesh=None,
                     rules: Optional[ShardingRules] = None,
                     moe_impl: str = "dense"):
    def serve_step(params, batch):
        with use_rules(rules, mesh):
            logits, new_cache = decode_step(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), vision=batch.get("vision"),
                cache=batch["cache"], index=batch["index"], window=window,
                moe_impl=moe_impl)
        return logits, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# Sharding specs for step inputs/outputs
# ---------------------------------------------------------------------------

def _axes(rules: ShardingRules, mesh, logical):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    r = rules.resolve(logical)
    if r is None:
        return None
    axes = tuple(ax for ax in (r if isinstance(r, tuple) else (r,))
                 if ax in sizes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def batch_shardings(batch_abstract, rules: ShardingRules, mesh,
                    grad_accum: int = 1):
    """Batch dim -> ('pod','data') when divisible, else replicated.

    With grad_accum > 1 batch leaves carry a leading (grad_accum,) scan
    dim that stays unsharded; the batch dim is index 1."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = _axes(rules, mesh, "batch")
    bsize = 1
    if baxes is not None:
        for ax in (baxes if isinstance(baxes, tuple) else (baxes,)):
            bsize *= sizes[ax]
    b_idx = 1 if grad_accum > 1 else 0

    def spec_for(path, leaf):
        names = [getattr(k, "key", None) for k in path]
        if "index" in names or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if "cache" in names:
            return NamedSharding(mesh, _cache_spec(names, leaf, rules, mesh,
                                                   baxes, bsize))
        spec = [None] * leaf.ndim
        if (baxes is not None and leaf.ndim > b_idx
                and leaf.shape[b_idx] % bsize == 0 and leaf.shape[b_idx] > 1):
            spec[b_idx] = baxes
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_for, batch_abstract)


def _cache_spec(names, leaf, rules, mesh, baxes, bsize):
    """KV cache (L, B, C, kvd) / conv (L, B, K, cd) / ssm (L, B, H, P, N).

    VLM self-cache has an extra leading dim.  Batch dim = the one sized
    like global batch — identified positionally: k/v/conv are ndim-3,
    ssm state is ndim-4.
    """
    taxes = _axes(rules, mesh, "tp")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tsize = 1
    if taxes is not None:
        for ax in (taxes if isinstance(taxes, tuple) else (taxes,)):
            tsize *= sizes[ax]
    spec = [None] * leaf.ndim
    if "ssm" in names and leaf.ndim >= 4 and names[-1] == "ssm":
        b_idx, t_idx = leaf.ndim - 4, leaf.ndim - 2      # (.., B, H, P, N)
    else:
        b_idx, t_idx = leaf.ndim - 3, leaf.ndim - 1      # (.., B, C, kvd)
    if baxes is not None and leaf.shape[b_idx] % bsize == 0 and leaf.shape[b_idx] > 1:
        spec[b_idx] = baxes
    if taxes is not None and leaf.shape[t_idx] % tsize == 0:
        spec[t_idx] = taxes
    return P(*spec)


def optimizer_shardings(opt_state_abstract, param_shardings, mesh):
    """m/v mirror the parameter shardings; step is replicated."""
    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=param_shardings,
        v=param_shardings,
    )


def train_shardings(cfg: ModelConfig, params_abstract, opt_state_abstract,
                    batch_abstract, rules: ShardingRules, mesh,
                    grad_accum: int = 1):
    pspec = param_spec_tree(params_abstract, rules, mesh)
    ospec = optimizer_shardings(opt_state_abstract, pspec, mesh)
    bspec = batch_shardings(batch_abstract, rules, mesh, grad_accum)
    scalar = NamedSharding(mesh, P())
    return (pspec, ospec, bspec), (pspec, ospec, scalar)


def _div_axes(rules, mesh, logical, dim):
    """Axes for ``logical`` only when they divide ``dim`` (else replicate)."""
    axes = _axes(rules, mesh, logical)
    if axes is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for ax in (axes if isinstance(axes, tuple) else (axes,)):
        total *= sizes[ax]
    return axes if (dim % total == 0 and dim > 1) else None


def serve_shardings(cfg: ModelConfig, params_abstract, batch_abstract,
                    rules: ShardingRules, mesh, *, global_batch: int,
                    cache_abstract=None):
    """Shardings for prefill (cache_abstract given) or decode steps."""
    pspec = param_spec_tree(params_abstract, rules, mesh)
    bspec = batch_shardings(batch_abstract, rules, mesh)
    logits = NamedSharding(mesh, P(
        _div_axes(rules, mesh, "batch", global_batch),
        _div_axes(rules, mesh, "tp", cfg.vocab_size)))
    if cache_abstract is not None:     # prefill: cache is an output
        cspec = batch_shardings({"cache": cache_abstract}, rules, mesh)["cache"]
        return (pspec, bspec), (logits, cspec)
    # decode: cache rides in and out through batch["cache"]
    return (pspec, bspec), (logits, bspec["cache"])
