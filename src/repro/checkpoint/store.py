"""Checkpointing: pytree save/restore (paper Sec. VII-b).

npz-based, dependency-free.  Supports per-stage checkpoints so a stage
replica can bootstrap a joining node ("downloads the weights of the stage
it will serve", Sec. V-E), plus full-model checkpoints for the launcher.

bf16 leaves are stored as uint16 bit patterns (npz cannot hold bf16)
with a ``bf16_<i>`` marker and reinterpreted through ``ml_dtypes`` on
restore; optimizer state (e.g. ``AdamWState``) round-trips like any
other pytree as long as the ``like`` template has the same structure.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np

try:
    import ml_dtypes
except ImportError:                                   # pragma: no cover
    ml_dtypes = None


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any, int]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.name == "bfloat16":       # npz cannot store bf16
            a = a.view(np.uint16)
            flat[f"bf16_{i}"] = np.asarray(1)
        flat[f"leaf_{i}"] = a
    return flat, treedef, len(leaves)


def save(path: str, tree, step: int = 0, meta: dict | None = None):
    """Atomically write ``<path>.npz`` (+ ``.json`` sidecar).

    Both files go through a same-directory temp file + ``os.replace``,
    so a crash mid-save (the fail-stop *and* the beyond-fail-stop
    churn models both kill nodes at arbitrary times) can never leave a
    truncated archive under the final name — a joining node
    bootstrapping from this checkpoint (Sec. V-E) either sees the old
    complete checkpoint or the new complete one.  The npz lands before
    the sidecar, so the sidecar never describes an archive that does
    not exist yet; a stale sidecar over a new archive fails loudly in
    `restore` via the leaf-count cross-check.
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, treedef, num_leaves = _flatten(tree)
    flat["__step"] = np.asarray(step)
    # np.savez appends ".npz" to string paths but not to file objects;
    # writing through a file object keeps the temp name exact
    npz_path = path if path.endswith(".npz") else path + ".npz"
    tmp = npz_path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, npz_path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    sidecar = {"treedef": str(treedef), "num_leaves": num_leaves,
               "step": step, **(meta or {})}
    tmp_json = npz_path + ".json.tmp"
    try:
        with open(tmp_json, "w") as f:
            json.dump(sidecar, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_json, npz_path + ".json")
    except BaseException:
        if os.path.exists(tmp_json):
            os.unlink(tmp_json)
        raise


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype template).

    The stored leaf count is validated against both the sidecar JSON
    (when present) and the template *before* unflattening, so a
    template/checkpoint mismatch fails with a structural error instead
    of a silent mis-assignment of leaves.
    """
    npz_path = path if path.endswith(".npz") else path + ".npz"
    data = np.load(npz_path)
    leaves, treedef = jax.tree.flatten(like)
    stored = sum(1 for k in data.files if k.startswith("leaf_"))
    if stored != len(leaves):
        raise ValueError(
            f"checkpoint {npz_path} holds {stored} leaves but the "
            f"restore template has {len(leaves)}: structure mismatch")
    sidecar_path = npz_path + ".json"
    if os.path.exists(sidecar_path):
        with open(sidecar_path) as f:
            sidecar = json.load(f)
        declared = sidecar.get("num_leaves")
        if declared is not None and declared != stored:
            raise ValueError(
                f"checkpoint {npz_path} is corrupt: sidecar declares "
                f"{declared} leaves, archive holds {stored}")
    loaded = []
    for i, l in enumerate(leaves):
        a = data[f"leaf_{i}"]
        if f"bf16_{i}" in data:
            if ml_dtypes is None:
                raise ImportError(
                    f"checkpoint {npz_path} contains bfloat16 leaves "
                    f"but the 'ml_dtypes' package is not installed; "
                    f"install it (it ships with jax) to restore bf16 "
                    f"checkpoints")
            a = a.view(ml_dtypes.bfloat16)
        loaded.append(a.astype(np.asarray(l).dtype))
    for got, want in zip(loaded, leaves):
        if got.shape != np.asarray(want).shape:
            raise ValueError(f"shape mismatch: {got.shape} vs "
                             f"{np.asarray(want).shape}")
    step = int(data["__step"]) if "__step" in data else 0
    return jax.tree.unflatten(treedef, loaded), step


def save_stage(dirpath: str, stage: int, params, step: int = 0):
    save(os.path.join(dirpath, f"stage_{stage:03d}.npz"), params, step=step)


def restore_stage(dirpath: str, stage: int, like):
    return restore(os.path.join(dirpath, f"stage_{stage:03d}.npz"), like)
