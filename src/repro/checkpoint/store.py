"""Checkpointing: pytree save/restore (paper Sec. VII-b).

npz-based, dependency-free.  Supports per-stage checkpoints so a stage
replica can bootstrap a joining node ("downloads the weights of the stage
it will serve", Sec. V-E), plus full-model checkpoints for the launcher.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.name == "bfloat16":       # npz cannot store bf16
            a = a.view(np.uint16)
            flat[f"bf16_{i}"] = np.asarray(1)
        flat[f"leaf_{i}"] = a
    return flat, treedef


def save(path: str, tree, step: int = 0, meta: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, treedef = _flatten(tree)
    flat["__step"] = np.asarray(step)
    np.savez(path, **flat)
    sidecar = {"treedef": str(treedef), "num_leaves": len(flat) - 1,
               "step": step, **(meta or {})}
    with open(path + ".json", "w") as f:
        json.dump(sidecar, f)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves, treedef = jax.tree.flatten(like)
    import ml_dtypes
    loaded = []
    for i, l in enumerate(leaves):
        a = data[f"leaf_{i}"]
        if f"bf16_{i}" in data:
            a = a.view(ml_dtypes.bfloat16)
        loaded.append(a.astype(np.asarray(l).dtype))
    for got, want in zip(loaded, leaves):
        if got.shape != np.asarray(want).shape:
            raise ValueError(f"shape mismatch: {got.shape} vs "
                             f"{np.asarray(want).shape}")
    step = int(data["__step"]) if "__step" in data else 0
    return jax.tree.unflatten(treedef, loaded), step


def save_stage(dirpath: str, stage: int, params, step: int = 0):
    save(os.path.join(dirpath, f"stage_{stage:03d}.npz"), params, step=step)


def restore_stage(dirpath: str, stage: int, like):
    return restore(os.path.join(dirpath, f"stage_{stage:03d}.npz"), like)
