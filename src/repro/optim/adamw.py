"""AdamW optimizer (pure JAX, optax-free) with FSDP-friendly state.

State mirrors the parameter pytree (m, v in float32) so the same
PartitionSpecs shard optimizer state across the 'data' axis (ZeRO-style) —
parameters can stay bf16 while moments and the update math run in f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state)."""
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1t = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2t = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / b1t
            vh = v / b2t
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decoupled decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - self.lr * delta).astype(p.dtype)
            return new_p, m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v)


@dataclass(frozen=True)
class SGD:
    """Plain SGD — the paper's convergence argument is stated for SGD."""
    lr: float = 1e-2
    momentum: float = 0.0

    def init(self, params):
        if self.momentum:
            return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return ()

    def update(self, grads, state, params):
        if self.momentum:
            new_state = jax.tree.map(
                lambda s, g: self.momentum * s + g.astype(jnp.float32),
                state, grads)
            new_p = jax.tree.map(
                lambda p, s: (p.astype(jnp.float32) - self.lr * s).astype(p.dtype),
                params, new_state)
            return new_p, new_state
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - self.lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, state
