"""Data pipeline: deterministic synthetic corpus + batching + microbatching.

The paper trains on Wikipedia-En; offline we use a synthetic Zipf-Markov
corpus with enough structure for the loss to fall (bigram dependencies) so
convergence comparisons (Fig. 6) are meaningful.  Data nodes each own a
disjoint shard (paper Sec. III: data nodes hold the training data).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int               # per data node, per iteration
    microbatch_size: int
    seed: int = 0


class SyntheticCorpus:
    """Zipf unigram + sticky bigram Markov chain: learnable structure."""

    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.2,
                 stickiness: float = 0.7):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self.unigram = ranks ** (-zipf_a)
        self.unigram /= self.unigram.sum()
        self.stickiness = stickiness
        # each token deterministically prefers a successor
        self.successor = self.rng.permutation(vocab_size)

    def sample(self, n_tokens: int) -> np.ndarray:
        out = np.empty(n_tokens, dtype=np.int32)
        out[0] = self.rng.choice(self.vocab, p=self.unigram)
        stick = self.rng.uniform(size=n_tokens) < self.stickiness
        rand = self.rng.choice(self.vocab, p=self.unigram, size=n_tokens)
        for i in range(1, n_tokens):
            out[i] = self.successor[out[i - 1]] if stick[i] else rand[i]
        return out


class DataNodeShard:
    """One data node's stream of (tokens, labels) batches."""

    def __init__(self, cfg: DataConfig, shard_id: int, num_shards: int):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg.vocab_size,
                                      seed=cfg.seed * 1000 + shard_id)

    def next_batch(self) -> dict:
        c = self.cfg
        toks = self.corpus.sample(c.batch_size * (c.seq_len + 1))
        toks = toks.reshape(c.batch_size, c.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def microbatches(self) -> List[dict]:
        b = self.next_batch()
        n = self.cfg.batch_size // self.cfg.microbatch_size
        return [{k: v[i * self.cfg.microbatch_size:(i + 1) * self.cfg.microbatch_size]
                 for k, v in b.items()} for i in range(n)]
