"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code annotates tensors with *logical* axis names via ``shard(x,
"batch", None, "tp")``; the active :class:`ShardingRules` maps logical
names to physical mesh axes.  With no active rules (unit tests, the
simulator) every annotation is a no-op, so the model code runs unchanged
on one CPU device.

Physical axes of the production mesh (see launch/mesh.py):
  * ``pod``   — outer data-parallel axis across pods (multi-pod only)
  * ``data``  — data parallel + FSDP (params/optimizer sharded here)
  * ``model`` — tensor parallel (d_ff, flattened head dims, vocab)
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]

_state = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    """Logical -> physical axis mapping."""
    batch: AxisName = ("pod", "data")
    fsdp: AxisName = "data"          # parameter / optimizer-state sharding
    tp: AxisName = "model"           # tensor parallel
    seq: AxisName = None             # sequence (context) parallel — off by default
    expert: AxisName = None          # expert parallel — off by default (tp shards d_ff)

    def resolve(self, logical: AxisName) -> AxisName:
        if logical is None:
            return None
        if isinstance(logical, tuple):
            parts = []
            for l in logical:
                r = self.resolve(l)
                if r is None:
                    continue
                parts.extend(r if isinstance(r, tuple) else (r,))
            return tuple(parts) if parts else None
        return getattr(self, logical)


def _mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules], mesh=None):
    prev = getattr(_state, "rules", None), getattr(_state, "mesh", None)
    _state.rules, _state.mesh = rules, mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def active_rules():
    return getattr(_state, "rules", None)


def active_mesh():
    return getattr(_state, "mesh", None)


def logical_spec(*logical_axes: AxisName) -> Optional[P]:
    rules = active_rules()
    if rules is None:
        return None
    return P(*(rules.resolve(a) for a in logical_axes))


def shard(x, *logical_axes: AxisName):
    """Annotate ``x`` with a sharding constraint; no-op without rules.

    Drops mesh axes that do not divide the dimension (keeps lowering
    robust for reduced smoke configs)."""
    rules = active_rules()
    mesh = active_mesh()
    if rules is None or mesh is None:
        return x
    sizes = _mesh_axis_sizes(mesh)
    resolved = []
    for dim, a in zip(x.shape, logical_axes):
        r = rules.resolve(a)
        if r is None:
            resolved.append(None)
            continue
        axes = tuple(ax for ax in (r if isinstance(r, tuple) else (r,))
                     if ax in sizes)
        total = 1
        for ax in axes:
            total *= sizes[ax]
        if not axes or total <= 1 or dim % total != 0:
            resolved.append(None)
        elif len(axes) == 1:
            resolved.append(axes[0])
        else:
            resolved.append(axes)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# Parameter partition specs
# ---------------------------------------------------------------------------

# rules keyed by parameter leaf name -> spec over the *trailing* dims.
_PARAM_RULES = {
    # attention
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    # dense mlp / shared expert
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"), "w_down": ("tp", "fsdp"),
    # mamba
    "in_proj": ("fsdp", "tp"), "out_proj": ("tp", "fsdp"),
    "conv_w": (None, "tp"), "conv_b": ("tp",),
    "A_log": (None,), "D": (None,), "dt_bias": (None,), "norm_scale": (None,),
    # moe (3-D expert-stacked) — handled by ndim below
    "router": ("fsdp", None),
    # embeddings
    "table": ("tp", "fsdp"),
    "lm_head": ("fsdp", "tp"),
    # vision projector
    "w_proj": (None, "fsdp"),
    # norms
    "scale": (None,), "bias": (None,),
}

_MOE_RULES = {
    "w_gate": ("expert", "fsdp", "tp"), "w_up": ("expert", "fsdp", "tp"),
    "w_down": ("expert", "tp", "fsdp"),
}


def param_spec_tree(params, rules: ShardingRules, mesh):
    """Build a PartitionSpec pytree for a params pytree.

    Leaves are matched by name; leading stacking dims (layer scan) get
    ``None``.  Mesh axes that do not divide a dim are dropped.
    """
    sizes = _mesh_axis_sizes(mesh)

    def present(axis):
        axes = tuple(ax for ax in (axis if isinstance(axis, tuple) else (axis,))
                     if ax in sizes)
        if not axes:
            return None
        return axes[0] if len(axes) == 1 else axes

    def divides(axis, dim):
        axes = axis if isinstance(axis, tuple) else (axis,)
        total = 1
        for ax in axes:
            total *= sizes.get(ax, 1)
        return dim % total == 0

    def spec_for(path, leaf):
        name = None
        moe = False
        for k in path:
            key = getattr(k, "key", getattr(k, "name", None))
            if key in ("w_gate", "w_up", "w_down") and leaf.ndim >= 3:
                moe = "shared" not in [getattr(kk, "key", None) for kk in path]
            if key in _PARAM_RULES or key in _MOE_RULES:
                name = key
        if name is None:
            return jax.sharding.NamedSharding(mesh, P())
        rule = _MOE_RULES[name] if (moe and name in _MOE_RULES) else _PARAM_RULES[name]
        ndim = leaf.ndim
        trailing = len(rule)
        spec = [None] * (ndim - trailing)
        for dim, logical in zip(leaf.shape[ndim - trailing:], rule):
            r = rules.resolve(logical)
            r = present(r) if r is not None else None
            if r is not None and divides(r, dim):
                spec.append(r)
            else:
                spec.append(None)
        return jax.sharding.NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shard_heads(x, head_dim_index: int):
    """Shard the heads dim over the tp axis, allowing uneven head counts
    (GSPMD pads).  Used for train/prefill attention where K/V stay
    replicated (GQA K/V are small) so Q.K^T needs no partial-sum
    all-reduce — the alternative (sharding head_dim) turns every score
    tensor into a giant all-reduce."""
    rules = active_rules()
    mesh = active_mesh()
    if rules is None or mesh is None:
        return x
    sizes = _mesh_axis_sizes(mesh)
    r = rules.resolve("tp")
    if r is None:
        return x
    axes = tuple(ax for ax in (r if isinstance(r, tuple) else (r,))
                 if ax in sizes)
    if not axes:
        return x
    spec = [None] * x.ndim
    spec[head_dim_index] = axes[0] if len(axes) == 1 else axes
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec)))


def tp_size() -> int:
    """Size of the resolved tp axes on the active mesh (1 if none)."""
    rules = active_rules()
    mesh = active_mesh()
    if rules is None or mesh is None:
        return 1
    sizes = _mesh_axis_sizes(mesh)
    r = rules.resolve("tp")
    if r is None:
        return 1
    total = 1
    for ax in (r if isinstance(r, tuple) else (r,)):
        total *= sizes.get(ax, 1)
    return total
