"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.configs import get_config
from repro.core.flow.graph import geo_distributed_network
from repro.core.simulator import ModelProfile, TrainingSimulator


def paper_network(model_arch: str, *, het: bool, seed: int,
                  num_stages: int = 6, relays: int = 16,
                  data_nodes: int = 2, data_capacity: int = 4):
    """The Sec. VI 'Node Crashes' setup: 18 nodes (2 data + 16 relays),
    6 stages, microbatch 4 x seq 512, activations x32, 10 locations,
    50-500 Mb/s links.  Heterogeneous caps U(1,3); homogeneous cap 4."""
    cfg = get_config(model_arch)
    prof = ModelProfile.from_config(cfg, num_stages=num_stages)
    rng = np.random.default_rng(seed)
    caps = ([int(rng.uniform(1, 4)) for _ in range(relays)] if het
            else [4] * relays)
    # 16 relays over 6 stages does not divide; the paper's first stage is
    # folded into the data node — we use 4 pipeline stages of 4 relays to
    # keep stages balanced (relative GWTF/SWARM ratios are the target).
    stages = 4
    net = geo_distributed_network(
        num_stages=stages, relay_capacities=caps,
        num_data_nodes=data_nodes, data_capacity=data_capacity,
        compute_cost=prof.fwd_compute,
        activation_size=prof.activation_bytes,
        rng=np.random.default_rng(seed))
    return net, prof


def crash_table(model_arch: str, *, reps: int = 5, iterations: int = 12,
                warmup: int = 2) -> List[Dict]:
    """One paper crash table (II or III): hom/het x {0,10,20}% churn,
    GWTF vs SWARM; metrics averaged over reps x iterations."""
    rows = []
    for het in (False, True):
        for churn in (0.0, 0.1, 0.2):
            cells = {}
            for sched in ("swarm", "gwtf"):
                tm, th, cm, wg = [], [], [], []
                for rep in range(reps):
                    net, prof = paper_network(model_arch, het=het, seed=rep)
                    sim = TrainingSimulator(
                        net, scheduler=sched, profile=prof, churn=churn,
                        rng=np.random.default_rng(rep + 1000))
                    ms = sim.run(iterations)[warmup:]
                    tm.append(np.mean([m.time_per_microbatch for m in ms]))
                    th.append(np.mean([m.completed for m in ms]))
                    cm.append(np.mean([m.comm_time for m in ms]))
                    wg.append(np.mean([m.wasted_gpu for m in ms]))
                cells[sched] = dict(
                    time_per_mb_min=(np.mean(tm) / 60, np.std(tm) / 60),
                    throughput=(np.mean(th), np.std(th)),
                    comm_min=(np.mean(cm) / 60, np.std(cm) / 60),
                    wasted_min=(np.mean(wg) / 60, np.std(wg) / 60))
            rows.append(dict(setting=("het" if het else "hom"),
                             churn=churn, **cells))
    return rows


def print_crash_table(title: str, rows: List[Dict]):
    print(f"\n=== {title} ===")
    hdr = f"{'setting':10s} {'metric':16s} {'SWARM':>16s} {'GWTF':>16s} {'GWTF win':>9s}"
    print(hdr)
    for r in rows:
        lab = f"{r['setting']} {int(r['churn']*100)}%"
        for metric, nice in (("time_per_mb_min", "min/microbatch"),
                             ("throughput", "throughput"),
                             ("comm_min", "comm time (min)"),
                             ("wasted_min", "wasted gpu (min)")):
            s_m, s_s = r["swarm"][metric]
            g_m, g_s = r["gwtf"][metric]
            better = g_m >= s_m if metric == "throughput" else g_m <= s_m
            print(f"{lab:10s} {nice:16s} {s_m:8.2f}±{s_s:5.2f} "
                  f"{g_m:8.2f}±{g_s:5.2f} {'GWTF' if better else 'SWARM':>9s}")
            lab = ""


def csv_row(name: str, value: float, derived: str = "") -> str:
    return f"{name},{value:.6g},{derived}"


def runtime_row(model_arch: str, *, churn: float = 0.1, iterations: int = 4,
                seed: int = 0, verbose: bool = True,
                activation_codec: str = "fp",
                wire_codec: str = "fp32") -> Dict:
    """One real-compute row through the staged runtime: the crash-table
    scenario (reduced to CPU scale) executed with actual JAX compute
    instead of the event simulator — losses, reroute/recompute counters,
    microbatches/sec, and the resident activation+residual store
    high-water mark from `repro.core.runtime` (fused dispatch;
    ``activation_codec="int8"`` measures the quantized store;
    ``wire_codec`` compresses inter-stage boundary transfers —
    ``"fp32"`` keeps them exact, ``"bf16"``/``"int8"``/``"top-k"``
    force one codec, ``"planner"`` follows the network's per-link
    codec-choice matrix)."""
    import dataclasses
    import time

    from repro.core.runtime.trainer import RuntimeTrainer
    from repro.data.pipeline import DataConfig, DataNodeShard

    cfg = dataclasses.replace(
        get_config(model_arch).reduced(num_layers=4, d_model=128),
        vocab_size=512)
    stages = 4
    net = geo_distributed_network(
        num_stages=stages, relay_capacities=[3] * (3 * stages),
        num_data_nodes=1, data_capacity=8,
        rng=np.random.default_rng(seed))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=1, seed=seed)
    shard = DataNodeShard(dc, 0, 1)
    tr = RuntimeTrainer(cfg, net, churn=churn, lr=1e-3, seed=seed,
                        activation_codec=activation_codec,
                        wire_codec=wire_codec)
    dn = net.data_nodes()[0].id
    tr.iteration({dn: shard.microbatches()})        # compile
    t0 = time.perf_counter()
    completed = rerouted = recomputes = dropped = store_peak = 0
    wire_bytes = 0
    for _ in range(iterations):
        r = tr.iteration({dn: shard.microbatches()})
        completed += r.completed
        rerouted += r.rerouted
        recomputes += r.fwd_recomputes + r.bwd_replays
        dropped += r.dropped
        store_peak = max(store_peak, r.store_peak_bytes)
        wire_bytes += r.wire_bytes
    dt = time.perf_counter() - t0
    row = dict(model=cfg.name, churn=churn, iterations=iterations,
               completed=completed, dropped=dropped, rerouted=rerouted,
               stage_recomputes=recomputes,
               mb_per_sec=round(completed / dt, 2),
               store_peak_bytes=store_peak,
               activation_codec=activation_codec,
               wire_codec=wire_codec, wire_bytes=wire_bytes,
               final_loss=round(tr.losses[-1], 4))
    if verbose:
        print(f"runtime row [{cfg.name}] churn={churn:.0%}: "
              f"{row['mb_per_sec']:.1f} mb/s, "
              f"{completed} completed / {dropped} dropped, "
              f"{rerouted} rerouted ({recomputes} stage recomputes), "
              f"store {store_peak / 1e6:.1f}MB ({activation_codec}), "
              f"wire {wire_codec} ({wire_bytes / 1e6:.1f}MB), "
              f"final loss {row['final_loss']:.4f}")
    return row
