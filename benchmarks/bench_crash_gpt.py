"""Paper Table III: the same crash-prone grid on the GPT-like model —
demonstrates model-agnosticism (Sec. VI 'GWTF is model-agnostic')."""
from benchmarks.common import crash_table, csv_row, print_crash_table


def run(reps: int = 5, iterations: int = 12, verbose: bool = True):
    rows = crash_table("gwtf-gpt-300m", reps=reps, iterations=iterations)
    if verbose:
        print_crash_table("Table III — GPT-like, crash-prone", rows)
    out = []
    for r in rows:
        lab = f"tableIII_{r['setting']}{int(r['churn']*100)}"
        s = r["swarm"]["time_per_mb_min"][0]
        g = r["gwtf"]["time_per_mb_min"][0]
        out.append(csv_row(f"{lab}_time_reduction", (s - g) / s if s else 0,
                           f"swarm={s:.2f}min gwtf={g:.2f}min"))
        out.append(csv_row(f"{lab}_gwtf_throughput",
                           r["gwtf"]["throughput"][0],
                           f"swarm={r['swarm']['throughput'][0]:.2f}"))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
