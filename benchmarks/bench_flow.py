"""Paper Fig. 7 / Table V: the flow algorithm on 6 abstract settings.

Average cost per microbatch after <=120 protocol iterations, GWTF vs the
SWARM greedy baseline (send to closest next-stage node), and vs the
Fulkerson-optimal for the single-source settings 1-4.
Paper claims: GWTF beats SWARM by up to 50%.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import synthetic_network
from repro.core.flow.mincost import solve_training_flow
from repro.core.swarm import SwarmRouter

SETTINGS = [  # Table V
    dict(name="1", sources=1, relays=40, stages=8, cap=(1, 3), cost=(1, 20)),
    dict(name="2", sources=1, relays=40, stages=10, cap=(1, 3), cost=(1, 20)),
    dict(name="3", sources=1, relays=40, stages=8, cap=(5, 15), cost=(1, 20)),
    dict(name="4", sources=1, relays=40, stages=8, cap=(1, 3), cost=(5, 100)),
    dict(name="5", sources=2, relays=40, stages=8, cap=(1, 3), cost=(1, 20)),
    dict(name="6", sources=4, relays=80, stages=8, cap=(1, 3), cost=(1, 20)),
]


def one(s, seed):
    rng = np.random.default_rng(seed)
    net, cost = synthetic_network(
        num_stages=s["stages"], relays_per_stage=s["relays"] // s["stages"],
        capacities=lambda r: int(r.uniform(*s["cap"])),
        link_costs=lambda r: float(int(r.uniform(*s["cost"]))),
        num_sources=s["sources"], source_capacity=4, rng=rng)
    # GWTF (sum objective — the paper's Fig.7 comparison basis)
    proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                         rng=np.random.default_rng(seed + 3))
    proto.run(max_rounds=120)
    flows = proto.complete_flows()
    gwtf = (proto.total_cost() / len(flows)) if flows else float("nan")
    # SWARM greedy (capacity-feasible: an over-committed schedule is not
    # executable, so greedy routes consume node slots)
    router = SwarmRouter(net, cost_matrix=cost,
                         rng=np.random.default_rng(seed + 5))
    costs = []
    used = {}
    for dn in net.data_nodes():
        for _ in range(dn.capacity):
            path = router.route_with_capacity(dn.id, used)
            if path:
                costs.append(sum(cost[path[i], path[i + 1]]
                                 for i in range(len(path) - 1)))
    swarm = float(np.mean(costs)) if costs else float("nan")
    # optimal (single-source formulations only)
    opt = float("nan")
    if s["sources"] == 1:
        k = max(len(flows), 1)
        plan = solve_training_flow(net, cost_matrix=cost, max_flow=k)
        opt = plan.cost / max(plan.flow, 1)
    return gwtf, swarm, opt


def run(reps: int = 5, verbose: bool = True):
    out = []
    if verbose:
        print("\n=== Fig. 7 — avg cost per microbatch (flow tests) ===")
        print(f"{'setting':8s} {'GWTF':>8s} {'SWARM':>8s} {'optimal':>8s} "
              f"{'vs SWARM':>9s}")
    for s in SETTINGS:
        vals = np.array([one(s, seed) for seed in range(reps)])
        g, sw, op = np.nanmean(vals, axis=0)
        win = (sw - g) / sw
        if verbose:
            o = f"{op:8.1f}" if np.isfinite(op) else "     n/a"
            print(f"{s['name']:8s} {g:8.1f} {sw:8.1f} {o} {win:9.1%}")
        out.append(csv_row(f"fig7_setting{s['name']}_gwtf_cost", g,
                           f"swarm={sw:.1f} opt={op:.1f} win={win:.1%}"))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
