"""Beyond-paper ablations on the flow protocol.

1. **Partial peer views** (paper Sec. III assumes partial membership
   knowledge but never quantifies it): flow quality vs the number of
   next-stage peers each node knows (DHT lookup size k).
2. **Annealing temperature**: T=0 (greedy local search) vs the paper's
   T=1.7/alpha=0.95 vs hot T=5.
3. **Timeout sensitivity** (Sec. V-D): time/mb vs the COMPLETE-timeout
   under churn — too short wastes reroutes, too long stalls recovery.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import geo_distributed_network, synthetic_network
from repro.core.flow.mincost import solve_training_flow
from repro.core.simulator import ModelProfile, TrainingSimulator


def _net(seed):
    rng = np.random.default_rng(seed)
    return synthetic_network(
        num_stages=8, relays_per_stage=5,
        capacities=lambda r: int(r.uniform(1, 3)),
        link_costs=lambda r: float(int(r.uniform(1, 20))),
        num_sources=1, source_capacity=4, rng=rng)


def peer_view_ablation(reps=5, verbose=True):
    rows = []
    if verbose:
        print("\n=== ablation: partial peer views (k next-stage peers) ===")
    for k in (1, 2, 3, 5, None):
        ratios, flows = [], []
        for seed in range(reps):
            net, cost = _net(seed)
            proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                                 peer_view=k,
                                 rng=np.random.default_rng(seed + 11))
            proto.run(max_rounds=200)
            n = len(proto.complete_flows())
            flows.append(n)
            if n:
                opt = solve_training_flow(net, cost_matrix=cost, max_flow=n)
                ratios.append(proto.total_cost() / max(opt.cost, 1e-9))
        lab = "full" if k is None else str(k)
        r = float(np.mean(ratios)) if ratios else float("nan")
        f = float(np.mean(flows))
        if verbose:
            print(f"  view={lab:4s}  flows={f:.1f}  cost/optimal={r:.2f}")
        rows.append(csv_row(f"ablate_peerview_{lab}", r, f"flows={f:.1f}"))
    return rows


def annealing_ablation(reps=5, verbose=True):
    rows = []
    if verbose:
        print("\n=== ablation: simulated annealing temperature ===")
    for T, alpha, lab in ((0.0, 0.95, "greedy"), (1.7, 0.95, "paper"),
                          (5.0, 0.99, "hot")):
        ratios = []
        for seed in range(reps):
            net, cost = _net(seed + 100)
            proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                                 temperature=T, alpha=alpha,
                                 rng=np.random.default_rng(seed + 21))
            proto.run(max_rounds=200)
            n = len(proto.complete_flows())
            if n:
                opt = solve_training_flow(net, cost_matrix=cost, max_flow=n)
                ratios.append(proto.total_cost() / max(opt.cost, 1e-9))
        r = float(np.mean(ratios))
        if verbose:
            print(f"  {lab:7s} (T={T}, a={alpha})  cost/optimal={r:.3f}")
        rows.append(csv_row(f"ablate_anneal_{lab}", r))
    return rows


def timeout_ablation(reps=3, verbose=True):
    rows = []
    if verbose:
        print("\n=== ablation: COMPLETE-timeout under 10% churn ===")
    prof = ModelProfile(fwd_compute=0.05)
    for timeout in (5.0, 30.0, 120.0, 600.0):
        tpm, waste = [], []
        for seed in range(reps):
            rng = np.random.default_rng(seed)
            caps = [int(rng.uniform(1, 4)) for _ in range(16)]
            net = geo_distributed_network(
                num_stages=4, relay_capacities=caps, num_data_nodes=2,
                data_capacity=4, compute_cost=0.05,
                rng=np.random.default_rng(seed))
            sim = TrainingSimulator(net, scheduler="gwtf", profile=prof,
                                    churn=0.1, timeout=timeout,
                                    rng=np.random.default_rng(seed + 5))
            ms = sim.run(8)[1:]
            tpm.append(np.mean([m.time_per_microbatch for m in ms]))
            waste.append(np.mean([m.wasted_gpu for m in ms]))
        t, w = float(np.mean(tpm)), float(np.mean(waste))
        if verbose:
            print(f"  timeout={timeout:6.0f}s  time/mb={t:7.1f}s "
                  f"waste={w:6.1f}s")
        rows.append(csv_row(f"ablate_timeout_{int(timeout)}", t,
                            f"waste={w:.1f}s"))
    return rows


def run(reps: int = 5, verbose: bool = True):
    return (peer_view_ablation(reps, verbose)
            + annealing_ablation(reps, verbose)
            + timeout_ablation(max(3, reps // 2), verbose))


if __name__ == "__main__":
    for line in run():
        print(line)
