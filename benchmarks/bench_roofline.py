"""Roofline analysis from the dry-run compile artifacts (harness req.).

For every (arch x shape x mesh) record in experiments/dryrun_*.json:

  compute term    = HLO dot FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO bytes accessed / HBM bandwidth   (per chip)
  collective term = collective bytes / ICI link bandwidth

(our HLO numbers are already per-partition, i.e. per chip — the SPMD
module is the per-device program).  MODEL_FLOPS uses 6ND (train) /
2ND (prefill) / 2N per token (decode) with N_active for MoE; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat & dense-MoE waste.

v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import INPUT_SHAPES


def active_params(cfg) -> float:
    """Parameters touched per token (MoE: shared + top-k experts only)."""
    n = cfg.param_count()
    if not cfg.is_moe:
        return n
    e_ff = 3 * cfg.d_model * cfg.d_ff
    routed_total = cfg.num_experts * e_ff * cfg.num_layers
    routed_active = cfg.num_experts_per_tok * e_ff * cfg.num_layers
    return n - routed_total + routed_active


def model_flops_per_chip(cfg, shape, chips: int) -> float:
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch / chips


def roofline_row(rec: Dict) -> Dict:
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    compute_s = rec["dot_flops"] / PEAK_FLOPS_BF16
    memory_s = rec["bytes_accessed"] / HBM_BW
    collective_s = rec["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_chip(cfg, shape, chips)
    ratio = mf / rec["dot_flops"] if rec["dot_flops"] else float("nan")
    return dict(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                moe_impl=rec.get("moe_impl", "dense"),
                compute_s=compute_s, memory_s=memory_s,
                collective_s=collective_s, dominant=dominant,
                model_flops=mf, hlo_flops=rec["dot_flops"],
                useful_ratio=ratio,
                temp_gb=rec["memory"]["temp_size"] / 1e9,
                analytic_gb=rec.get("analytic_memory", {}).get("total", 0) / 1e9)


def load(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    return json.load(open(path))


def run(verbose: bool = True,
        paths=("experiments/dryrun_singlepod.json",)):
    rows = []
    for p in paths:
        rows += [roofline_row(r) for r in load(p)]
    if verbose and rows:
        print("\n=== Roofline (per chip, seconds per step) ===")
        print(f"{'arch':22s} {'shape':12s} {'mesh':8s} {'compute':>9s} "
              f"{'memory':>9s} {'collect':>9s} {'dominant':>10s} "
              f"{'useful':>7s}")
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                  f"{r['compute_s']:9.2e} {r['memory_s']:9.2e} "
                  f"{r['collective_s']:9.2e} {r['dominant']:>10s} "
                  f"{r['useful_ratio']:7.2f}")
    out = []
    for r in rows:
        out.append(csv_row(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]),
            f"dom={r['dominant']} c={r['compute_s']:.2e} "
            f"m={r['memory_s']:.2e} x={r['collective_s']:.2e} "
            f"useful={r['useful_ratio']:.2f}"))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
