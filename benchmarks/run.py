"""Benchmark harness: one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick pass
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale reps

Prints ``name,value,derived`` CSV rows (plus human-readable tables).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale repetition counts (25 reps)")
    ap.add_argument("--only", default=None,
                    help="comma list: crash_llama,crash_gpt,node_addition,"
                         "optimal,flow,convergence,roofline,ablation")
    args = ap.parse_args()
    reps = 25 if args.full else 3
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (bench_ablation, bench_convergence,
                            bench_crash_gpt, bench_crash_llama, bench_flow,
                            bench_node_addition, bench_optimal,
                            bench_roofline)

    suites = [
        ("crash_llama", lambda: bench_crash_llama.run(reps=reps)),
        ("crash_gpt", lambda: bench_crash_gpt.run(reps=reps)),
        ("node_addition", lambda: bench_node_addition.run(
            reps=max(2, reps // 2))),
        ("optimal", lambda: bench_optimal.run(reps=reps)),
        ("flow", lambda: bench_flow.run(reps=max(3, reps))),
        ("convergence", lambda: bench_convergence.run(
            iterations=40 if args.full else 15)),
        ("roofline", bench_roofline.run),
        ("ablation", lambda: bench_ablation.run(reps=max(4, reps // 2))),
    ]

    all_rows = []
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        rows = fn()
        all_rows += rows
        print(f"[{name}: {time.time()-t0:.1f}s]")

    print("\n# name,value,derived")
    for row in all_rows:
        print(row)


if __name__ == "__main__":
    main()
