"""Scenario-corpus benchmark: per-scenario wall time across the layers.

Scenario breadth is a tracked perf surface: every committed corpus
scenario is materialized and timed through

* **flow** — the batched `GWTFProtocol` run (plan construction),
* **oracle** — the `MinCostFlow` optimum (auto method),
* **sim** — the full `TrainingSimulator` run (`spec.iterations`
  iterations, planning + event loop),
* **runtime** (``--runtime`` only; needs JAX) — the reduced
  real-compute `RuntimeTrainer` run.

``--json PATH`` writes the table for tracking; ``--fuzz SECONDS`` runs
the seeded differential fuzz session from `scenarios.harness` after
the sweep and fails the process on any discrepancy (the CI scenarios
job uses the pytest entry point instead, but this keeps the whole
surface drivable from one command line).  Numpy-only unless
``--runtime`` is given.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from repro.core.scenarios import generate
from repro.core.scenarios.corpus import load_corpus

REPO_ROOT = Path(__file__).resolve().parent.parent


def bench_scenario(spec, runtime: bool = False) -> dict:
    row = {"name": spec.name, "topology": spec.topology,
           "nodes": spec.base_nodes + spec.spare_nodes,
           "stages": spec.num_stages,
           "churn": ",".join(c["kind"] for c in spec.churn) or "-"}
    t0 = time.perf_counter()
    flow = generate.run_flow(spec, "batched")
    row["flow_s"] = time.perf_counter() - t0
    row["chains"] = len(flow.flows)
    t0 = time.perf_counter()
    generate.solve_optimal(spec)
    row["oracle_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    metrics = generate.run_sim(spec)
    row["sim_s"] = time.perf_counter() - t0
    row["sim_events"] = sum(m.events for m in metrics)
    if runtime:
        t0 = time.perf_counter()
        generate.run_runtime(spec, iterations=min(spec.iterations, 2))
        row["runtime_s"] = time.perf_counter() - t0
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--runtime", action="store_true",
                    help="also time the reduced real-compute runtime "
                         "(imports JAX)")
    ap.add_argument("--scenario", action="append", default=None,
                    help="restrict to named scenario(s)")
    ap.add_argument("--fuzz", type=float, default=0.0, metavar="SECONDS",
                    help="run the seeded differential fuzz session for "
                         "SECONDS after the sweep; non-zero exit on any "
                         "discrepancy")
    ap.add_argument("--json", type=Path, default=None,
                    help="write the rows to this path")
    args = ap.parse_args(argv)

    specs = load_corpus()
    if args.scenario:
        specs = [s for s in specs if s.name in set(args.scenario)]
        if not specs:
            print(f"no scenarios match {args.scenario}", file=sys.stderr)
            return 2

    rows = []
    hdr = (f"{'scenario':28s} {'topo':9s} {'nodes':>5s} {'chains':>6s} "
           f"{'flow s':>7s} {'oracle s':>8s} {'sim s':>7s}"
           + ("  runtime s" if args.runtime else ""))
    print(hdr)
    print("-" * len(hdr))
    for spec in specs:
        row = bench_scenario(spec, runtime=args.runtime)
        rows.append(row)
        line = (f"{row['name']:28s} {row['topology']:9s} "
                f"{row['nodes']:5d} {row['chains']:6d} "
                f"{row['flow_s']:7.3f} {row['oracle_s']:8.3f} "
                f"{row['sim_s']:7.3f}")
        if args.runtime:
            line += f" {row['runtime_s']:10.3f}"
        print(line)
    total = sum(r["flow_s"] + r["oracle_s"] + r["sim_s"] +
                r.get("runtime_s", 0.0) for r in rows)
    print(f"{len(rows)} scenarios, {total:.2f}s total")

    if args.json:
        args.json.write_text(json.dumps(
            {"rows": rows, "total_seconds": total}, indent=2) + "\n")
        print(f"wrote {args.json}")

    if args.fuzz > 0:
        from repro.core.scenarios.harness import FUZZ_CHECKS, fuzz
        rep = fuzz(seed=20260728, budget_seconds=args.fuzz,
                   checks=FUZZ_CHECKS)
        print(f"fuzz: {rep.cases} cases in {rep.elapsed:.1f}s, "
              f"{len(rep.failures)} discrepancies")
        for f in rep.failures:
            print(f"  FAIL [{f.check}] {f.detail}")
            print(f"  minimized spec:\n{f.minimized.to_json()}")
        if rep.failures:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
