"""Paper Fig. 6: loss convergence — GWTF at 10% churn vs centralized.

Real JAX training through GWTF-routed stage replicas (reduced model scale
for CPU).  The claim: GWTF does not alter training semantics, so the loss
curves coincide up to the microbatches dropped by churn.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core.executor import CentralizedTrainer, DecentralizedTrainer
from repro.core.flow.graph import geo_distributed_network
from repro.data.pipeline import DataConfig, DataNodeShard


def run(iterations: int = 30, verbose: bool = True):
    cfg = get_config("gwtf-llama-300m").reduced(num_layers=4, d_model=128)
    cfg = dataclasses.replace(cfg, vocab_size=512)
    stages = 4
    net = geo_distributed_network(
        num_stages=stages, relay_capacities=[3] * 12, num_data_nodes=1,
        data_capacity=8, rng=np.random.default_rng(0))
    dec = DecentralizedTrainer(cfg, net, churn=0.1, lr=2e-3, seed=0)
    cen = CentralizedTrainer(cfg, stages, lr=2e-3, seed=0)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=16,
                    microbatch_size=2, seed=0)
    shard = DataNodeShard(dc, 0, 1)
    dn = net.data_nodes()[0].id

    for it in range(iterations):
        mbs = shard.microbatches()
        r = dec.iteration({dn: mbs})
        cl = cen.iteration(mbs)
        if verbose and it % 5 == 0:
            print(f"iter {it:3d}: gwtf(10% churn)={r.loss:.4f} "
                  f"[{r.completed}/{r.launched}]  centralized={cl:.4f}")

    g = float(np.mean([l for l in dec.losses[-5:] if l > 0]))
    c = float(np.mean(cen.losses[-5:]))
    gap = abs(g - c)
    if verbose:
        print(f"final-5 mean: gwtf={g:.4f} centralized={c:.4f} gap={gap:.4f}")
        print("paper Fig. 6: curves coincide — same SGD semantics.")
    return [csv_row("fig6_final_loss_gwtf", g, f"centralized={c:.4f}"),
            csv_row("fig6_convergence_gap", gap,
                    "loss-gap after equal iterations")]


if __name__ == "__main__":
    for line in run():
        print(line)
