"""Real-compute executor benchmark: fused staged runtime vs remat vs
frozen full-jit reference, plus the quantized activation store.

Runs the same seeded churn-free training iterations (reduced 300M
family config) through

* the **fused staged runtime** (`repro.core.runtime`, default): one
  residual-capturing dispatch per stage forward, backward consumes the
  stored residuals — no forward rematerialisation anywhere;
* the **remat oracle** (``RuntimeTrainer(remat=True)``): the
  pre-rework behaviour, every backward re-runs the stage forward from
  the stored boundary activation (kept as the in-engine bit-equality
  oracle);
* the **int8 store** (``activation_codec="int8"``): the fused path
  with per-tensor symmetric int8(+fp32 scale) quantisation of boundary
  activations and residuals — the memory/fidelity trade, reported
  non-gating;
* the **frozen reference** (`repro.core.runtime.reference`): the
  pre-refactor executor, one whole-model ``value_and_grad`` dispatch
  per microbatch,

and measures **microbatches/sec** (completed microbatches per second
of iteration wall time, compile excluded), the **resident
activation-store bytes** (high-water encoded bytes of boundaries +
residuals), and the **end-of-run loss delta** of the int8 path vs the
fp path on the identical seeded run.

It also measures **recovery cost** per crashed microbatch: replaying a
backward crash from stored residuals (zero forward recompute) vs the
rematerialising stage replay vs the full-pipeline recompute a
restart-based scheduler pays.

Results go to ``BENCH_exec.json``.  ``--smoke`` runs the small sizes
only and gates against the committed JSON: it exits non-zero if

* the dispatch-bound fused-vs-reference speedup fell below 1.3x
  (single-core reference timing is noisy, so the absolute ratio gate
  is conservative; the tight bound is the floor below),
* the compute-bound row regressed to remat-level throughput
  (fused-vs-remat speedup below 1.1x best-of-two, measured in-run so
  the gate is host-independent), or
* fused microbatches/sec regressed past the host-normalized floor
  (committed value scaled by the reference's in-run speed, / 1.5; the
  host factor is clamped at 1.0 — it discounts slower CI hosts, it
  never raises the bar when the reference happens to run fast), or
* a **wire codec** (``wire_codec=`` forced bf16/int8/top-k on the
  inter-stage boundary transfers) broke fidelity — end-of-run loss
  delta vs the fp32 wire above its ceiling — or stopped compressing
  (encoded bytes reduction below the codec's floor).  Both wire gates
  are in-run ratios/deltas, so they are host-independent, or
* the **byzantine record** (``byzantine`` key) broke: the same seeded
  run is trained three ways — clean, with a corrupt-gradient adversary
  on 1 of 6 relays (>= 10% of the compute fleet, seeded "perturb"
  noise) and the gradient screen disabled, and with the adversary plus
  the screen (lower-median norm + leave-one-out cosine test before
  AdamW aggregation, detection feeding the reputation/quarantine
  layer).  The gate pins the end-of-run |loss - clean loss| deltas:
  the defended run must stay below a fixed ceiling while the
  undefended run exceeds it, and the screen must actually detect the
  corrupt node (timeline detections > 0, corrupt node quarantined).
  All three are in-run loss/count comparisons — host-independent.

The int8 store row is reported but never gates.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_exec.json"

SEED = 0
ITERATIONS = 3

# (label, layers, d_model, seq_len, microbatch, num_microbatches, stages)
FULL_ROWS = [
    ("dispatch_bound", 4, 128, 32, 1, 32, 4),   # headline: >= 2x gated
    ("mixed", 4, 128, 64, 1, 32, 4),
    ("compute_bound", 4, 128, 128, 1, 32, 4),   # the old remat-floor row
]
SMOKE_ROWS = [
    ("dispatch_bound", 2, 128, 32, 1, 16, 2),
    ("compute_bound", 2, 128, 128, 1, 16, 2),
]

# Wire-codec row: the mixed shape rerun with each inter-stage wire
# codec forced on the boundary-chunk transfers (forward path only;
# cotangents stay exact).  The loss-delta ceilings are generous on
# purpose: they catch a broken encode/decode pair, not normal
# quantisation drift on this seeded run.
WIRE_ROW = (2, 128, 64, 1, 16, 2)      # layers d_model seq mb n_mb stages
WIRE_CODECS_MEASURED = ("bf16", "int8", "top-k")
WIRE_LOSS_DELTA_MAX = {"bf16": 0.05, "int8": 0.5, "top-k": 2.5}
WIRE_BYTES_REDUCTION_MIN = {"bf16": 1.9, "int8": 3.0, "top-k": 6.0}

# Byzantine record: tiny 2-stage topology (6 relays, node 2 corrupt =
# 1/6 >= 10% of the compute fleet), seeded "perturb" corruption of
# every contribution whose chain crosses the corrupt node.  The loss
# ceiling splits the observed deltas (defended ~0.15, undefended
# ~0.39 on this seeded run) with margin on both sides; all gates
# compare quantities from the same run, so they are host-independent.
BYZ_ROW = (2, 32, 16, 1, 4, 2)         # layers d_model seq mb n_mb stages
BYZ_CORRUPT_NODES = (2,)
BYZ_MODE = "perturb"
BYZ_SCALE = 1.0
BYZ_FAULT_SEED = 7
BYZ_ITERATIONS = 6
BYZ_LOSS_DELTA_CEILING = 0.25


def _build(label, layers, d_model, seq, mbsz, n_mb, stages):
    from repro.configs import get_config
    from repro.core.flow.graph import geo_distributed_network
    from repro.data.pipeline import DataConfig, DataNodeShard

    cfg = dataclasses.replace(
        get_config("gwtf-llama-300m").reduced(num_layers=layers,
                                              d_model=d_model),
        vocab_size=512)

    def make_net():
        return geo_distributed_network(
            num_stages=stages, relay_capacities=[16] * (3 * stages),
            num_data_nodes=1, data_capacity=n_mb,
            rng=np.random.default_rng(SEED))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                    batch_size=n_mb * mbsz, microbatch_size=mbsz, seed=SEED)
    mbs = DataNodeShard(dc, 0, 1).microbatches()
    return cfg, make_net, mbs


def _throughput(trainer, mbs, iterations=ITERATIONS):
    dn = 0
    trainer.iteration({dn: mbs})           # compile + warm caches
    t0 = time.perf_counter()
    done = 0
    peak = 0
    r = None
    for _ in range(iterations):
        r = trainer.iteration({dn: mbs})
        done += r.completed
        peak = max(peak, getattr(r, "store_peak_bytes", 0))
    dt = time.perf_counter() - t0
    return done / dt, done, peak, r.loss


def _runtime(cfg, net, **kw):
    from repro.core.runtime.trainer import RuntimeTrainer
    from repro.core.sim.faults import TraceChurn

    return RuntimeTrainer(cfg, net, lr=1e-3, seed=SEED,
                          churn_model=TraceChurn([]), **kw)


def bench_row(label, layers, d_model, seq, mbsz, n_mb, stages) -> dict:
    from repro.core.runtime.reference import ReferenceDecentralizedTrainer

    cfg, make_net, mbs = _build(label, layers, d_model, seq, mbsz, n_mb,
                                stages)
    fused_mbs, fused_done, fused_peak, fused_loss = _throughput(
        _runtime(cfg, make_net()), mbs)
    remat_mbs, _, remat_peak, _ = _throughput(
        _runtime(cfg, make_net(), remat=True), mbs)
    int8_mbs, _, int8_peak, int8_loss = _throughput(
        _runtime(cfg, make_net(), activation_codec="int8"), mbs)
    ref = ReferenceDecentralizedTrainer(cfg, make_net(), churn=0.0,
                                        lr=1e-3, seed=SEED)
    ref_mbs, ref_done = _throughput(ref, mbs)[:2]
    return dict(
        label=label, layers=layers, d_model=d_model, seq_len=seq,
        microbatch=mbsz, num_microbatches=n_mb, stages=stages,
        runtime_mb_per_sec=round(fused_mbs, 2),
        runtime_remat_mb_per_sec=round(remat_mbs, 2),
        int8_mb_per_sec=round(int8_mbs, 2),
        reference_mb_per_sec=round(ref_mbs, 2),
        speedup=round(fused_mbs / ref_mbs, 2),
        speedup_vs_remat=round(fused_mbs / remat_mbs, 2),
        resident_act_bytes=int(fused_peak),
        remat_resident_act_bytes=int(remat_peak),
        int8_resident_act_bytes=int(int8_peak),
        act_bytes_reduction=round(fused_peak / max(1, int8_peak), 2),
        loss_final_fp=round(float(fused_loss), 6),
        int8_loss_delta=round(abs(float(int8_loss) - float(fused_loss)), 6),
        completed=(fused_done, ref_done),
    )


def bench_recovery(layers=4, d_model=128, seq=64, stages=4) -> dict:
    """Per-crashed-microbatch repair cost, three ways: replay the
    stage VJP from stored residuals (fused path, zero forward
    recompute), rematerialising stage replay from the stored boundary
    activation (GWTF pre-rework, Sec. V-D), and the full-pipeline
    recompute a restart-based scheduler pays."""
    import jax
    import jax.numpy as jnp

    from repro.core.runtime.cache import initial_params
    from repro.core.runtime.stages import (StageCompute, embed_fn,
                                           loss_fn, stage_forward)
    from repro.configs import get_config

    cfg = dataclasses.replace(
        get_config("gwtf-llama-300m").reduced(num_layers=layers,
                                              d_model=d_model),
        vocab_size=512)
    stage_params, head = initial_params(cfg, stages, SEED)
    sc = StageCompute(cfg, stages)
    rng = np.random.default_rng(SEED)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)))
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)))
    x = sc.embed(head, tokens)
    _, resid = sc.forward_fused(0, stage_params[0], x)

    def full(head_p, stage_ps, toks, labs):
        h = embed_fn(head_p, toks)
        for s in range(stages):
            h = stage_forward(stage_ps[s], h, cfg)
        return loss_fn(head_p, h, labs, cfg)

    full_grad = jax.jit(jax.value_and_grad(full, argnums=(0, 1)))

    def timed(fn, reps=20):
        fn()                                   # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return (time.perf_counter() - t0) / reps

    # a fresh cotangent per call: the backward dispatch donates its
    # cotangent buffer on donating backends, so reusing `g` would
    # crash there (stored activations/residuals are never donated)
    residual_ms = timed(lambda: jax.block_until_ready(
        sc.backward_from_residuals(0, resid, jnp.ones_like(x)))) * 1e3
    remat_ms = timed(lambda: jax.block_until_ready(
        sc.backward(0, stage_params[0], x, jnp.ones_like(x)))) * 1e3
    full_ms = timed(lambda: jax.block_until_ready(
        full_grad(head, list(stage_params), tokens, labels))) * 1e3
    return dict(layers=layers, d_model=d_model, seq_len=seq, stages=stages,
                stage_replay_residual_ms=round(residual_ms, 3),
                stage_replay_remat_ms=round(remat_ms, 3),
                full_pipeline_ms=round(full_ms, 3),
                remat_over_residual=round(remat_ms / residual_ms, 2),
                full_over_residual=round(full_ms / residual_ms, 2))


def bench_wire(layers=WIRE_ROW[0], d_model=WIRE_ROW[1], seq=WIRE_ROW[2],
               mbsz=WIRE_ROW[3], n_mb=WIRE_ROW[4],
               stages=WIRE_ROW[5]) -> dict:
    """Forced wire codecs on the identical seeded churn-free run:
    microbatches/sec, encoded bytes actually shipped across stage
    boundaries, and the end-of-run loss delta vs the exact-fp32 wire."""
    cfg, make_net, mbs = _build("wire", layers, d_model, seq, mbsz, n_mb,
                                stages)
    fp_mbs, fp_done, _, fp_loss = _throughput(_runtime(cfg, make_net()), mbs)
    # raw boundary traffic per iteration: every completed microbatch
    # crosses stages-1 boundaries as fp32 rows
    raw = fp_done // ITERATIONS * seq * d_model * 4 * (stages - 1)
    codecs = {}
    for codec in WIRE_CODECS_MEASURED:
        tr = _runtime(cfg, make_net(), wire_codec=codec)
        c_mbs, _, _, c_loss = _throughput(tr, mbs)
        enc = int(tr.last_wire_bytes)
        codecs[codec] = dict(
            mb_per_sec=round(c_mbs, 2),
            wire_bytes_per_iter=enc,
            wire_bytes_reduction=round(raw / max(1, enc), 2),
            loss_delta=round(abs(float(c_loss) - float(fp_loss)), 6))
    return dict(
        layers=layers, d_model=d_model, seq_len=seq, microbatch=mbsz,
        num_microbatches=n_mb, stages=stages,
        fp32_mb_per_sec=round(fp_mbs, 2),
        loss_final_fp32=round(float(fp_loss), 6),
        raw_wire_bytes_per_iter=int(raw),
        codecs=codecs)


def bench_byzantine() -> dict:
    """The same seeded run trained three ways: clean, corrupt relay
    with the gradient screen off, corrupt relay with the screen on
    (auto-enabled; detection feeds the reputation/quarantine layer).
    Everything reported is a loss/count from within this run, so the
    smoke gates on it are host-independent."""
    from repro.configs import get_config
    from repro.core.flow.graph import geo_distributed_network
    from repro.core.runtime.trainer import RuntimeTrainer
    from repro.core.sim.faults import CorruptGradientChurn
    from repro.data.pipeline import DataConfig, DataNodeShard

    layers, d_model, seq, mbsz, n_mb, stages = BYZ_ROW
    cfg = dataclasses.replace(
        get_config("gwtf-llama-300m").reduced(num_layers=layers,
                                              d_model=d_model),
        vocab_size=512)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                    batch_size=n_mb * mbsz, microbatch_size=mbsz, seed=3)
    mbs = DataNodeShard(dc, 0, 1).microbatches()

    def run(corrupt: bool, screen):
        net = geo_distributed_network(
            num_stages=stages, relay_capacities=[2] * (3 * stages),
            num_data_nodes=1, data_capacity=n_mb,
            rng=np.random.default_rng(SEED))
        kw = {}
        if corrupt:
            kw["churn_model"] = CorruptGradientChurn(
                list(BYZ_CORRUPT_NODES), mode=BYZ_MODE, scale=BYZ_SCALE,
                seed=BYZ_FAULT_SEED, known_ids=net.nodes.keys())
        tr = RuntimeTrainer(cfg, net, lr=1e-3, seed=SEED,
                            grad_screen=screen, **kw)
        losses, flagged = [], 0
        ever_quarantined = set()
        for _ in range(BYZ_ITERATIONS):
            r = tr.iteration({0: mbs})
            losses.append(round(float(r.loss), 6))
            flagged += r.grads_flagged
            # the decay rehabilitation lifts the node back over the
            # quarantine threshold within a few clean iterations, so
            # quarantine is checked after every commit, not at the end
            ever_quarantined.update(n for n in BYZ_CORRUPT_NODES
                                    if net.quarantined(n))
        counts = tr.timeline.counts()
        detections = sum(c for (_, fault, kind), c in counts.items()
                         if fault == "corrupt_gradient"
                         and kind == "detection")
        return dict(losses=losses, flagged=flagged, detections=detections,
                    quarantined=sorted(ever_quarantined),
                    reputation={n: round(net.reputation(n), 4)
                                for n in BYZ_CORRUPT_NODES})

    clean = run(False, None)
    undefended = run(True, False)
    defended = run(True, None)
    return dict(
        layers=layers, d_model=d_model, seq_len=seq, microbatch=mbsz,
        num_microbatches=n_mb, stages=stages, iterations=BYZ_ITERATIONS,
        corrupt_nodes=list(BYZ_CORRUPT_NODES), mode=BYZ_MODE,
        scale=BYZ_SCALE, fault_seed=BYZ_FAULT_SEED,
        corrupt_fraction=round(len(BYZ_CORRUPT_NODES) / (3 * stages), 3),
        loss_ceiling=BYZ_LOSS_DELTA_CEILING,
        losses_clean=clean["losses"],
        losses_undefended=undefended["losses"],
        losses_defended=defended["losses"],
        loss_delta_undefended=round(
            abs(undefended["losses"][-1] - clean["losses"][-1]), 6),
        loss_delta_defended=round(
            abs(defended["losses"][-1] - clean["losses"][-1]), 6),
        grads_flagged=(defended["flagged"], undefended["flagged"],
                       clean["flagged"]),
        detections=defended["detections"],
        quarantined_during_run=defended["quarantined"],
        corrupt_reputation_final=defended["reputation"])


def print_byzantine(b: dict):
    print(f"  byzantine       L{b['layers']} d{b['d_model']} "
          f"seq{b['seq_len']:4d} S{b['stages']}: corrupt nodes "
          f"{b['corrupt_nodes']} ({100 * b['corrupt_fraction']:.0f}% of "
          f"relays, {b['mode']} x{b['scale']})")
    print(f"  {'':15s} end-loss delta vs clean: defended "
          f"{b['loss_delta_defended']:.4f} / undefended "
          f"{b['loss_delta_undefended']:.4f} (ceiling "
          f"{b['loss_ceiling']})  detections={b['detections']} "
          f"flagged={b['grads_flagged'][0]} "
          f"quarantined={b['quarantined_during_run']} "
          f"final rep={b['corrupt_reputation_final']}")


def print_wire(w: dict):
    print(f"  wire codecs     L{w['layers']} d{w['d_model']} "
          f"seq{w['seq_len']:4d} S{w['stages']}: fp32 "
          f"{w['fp32_mb_per_sec']:8.1f} mb/s, "
          f"{w['raw_wire_bytes_per_iter'] / 1e6:.2f} MB/iter on wire")
    for codec, c in w["codecs"].items():
        print(f"  {'':15s} {codec:5s} {c['mb_per_sec']:8.1f} mb/s  "
              f"wire {c['wire_bytes_per_iter'] / 1e6:6.2f} MB/iter "
              f"({c['wire_bytes_reduction']:.2f}x smaller)  "
              f"loss delta {c['loss_delta']:.4f} "
              f"(ceiling {WIRE_LOSS_DELTA_MAX[codec]})")


def print_row(r: dict):
    print(f"  {r['label']:15s} L{r['layers']} d{r['d_model']} "
          f"seq{r['seq_len']:4d} mb{r['microbatch']}x"
          f"{r['num_microbatches']:3d} S{r['stages']}: "
          f"fused {r['runtime_mb_per_sec']:8.1f} mb/s  "
          f"remat {r['runtime_remat_mb_per_sec']:8.1f}  "
          f"reference {r['reference_mb_per_sec']:8.1f}  "
          f"speedup {r['speedup']:.2f}x (vs remat "
          f"{r['speedup_vs_remat']:.2f}x)")
    print(f"  {'':15s} int8 {r['int8_mb_per_sec']:8.1f} mb/s  "
          f"store {r['resident_act_bytes'] / 1e6:7.1f} MB -> "
          f"{r['int8_resident_act_bytes'] / 1e6:.1f} MB "
          f"({r['act_bytes_reduction']:.2f}x smaller)  "
          f"loss delta {r['int8_loss_delta']:.4f} "
          f"[non-gating]")


def smoke(committed_path: Path) -> int:
    """CI gate: fail if the fused runtime regressed past the
    host-normalized floor, the dispatch-bound speedup dropped below
    1.3x, or the compute-bound row fell back to remat-level
    throughput."""
    committed = {}
    if committed_path.exists():
        data = json.loads(committed_path.read_text())
        committed = {r["label"]: r for r in data.get("smoke_results", [])}
    else:
        print(f"no committed {committed_path.name}; smoke run is "
              f"informational only")
    failures = []
    print("== bench_exec --smoke ==")
    for row in SMOKE_ROWS:
        rec = bench_row(*row)
        print_row(rec)
        if (rec["label"] == "compute_bound"
                and rec["speedup_vs_remat"] < 1.1):
            # the in-run fused/remat ratio at smoke scale swings with
            # background load (observed 1.1-1.5x on the same host);
            # retry once and take the better sample before declaring
            # the fused-dispatch win gone
            retry = bench_row(*row)
            print_row(retry)
            if retry["speedup_vs_remat"] > rec["speedup_vs_remat"]:
                rec = retry
        if rec["label"] == "dispatch_bound" and rec["speedup"] < 1.3:
            failures.append(
                f"{rec['label']}: batched fused speedup "
                f"{rec['speedup']:.2f}x < 1.3x over the per-microbatch "
                f"full-jit reference")
        if rec["label"] == "compute_bound" and rec["speedup_vs_remat"] < 1.1:
            failures.append(
                f"{rec['label']}: fused path at remat-level throughput "
                f"({rec['speedup_vs_remat']:.2f}x < 1.1x vs the in-run "
                f"remat oracle — the fused dispatch win is gone)")
        base = committed.get(rec["label"])
        if base is not None and "runtime_mb_per_sec" in base:
            host = min(1.0, rec["reference_mb_per_sec"]
                       / base["reference_mb_per_sec"])
            floor = base["runtime_mb_per_sec"] * host / 1.5
            print(f"    gate: measured {rec['runtime_mb_per_sec']:.1f} mb/s "
                  f"vs floor {floor:.1f} mb/s (committed "
                  f"{base['runtime_mb_per_sec']:.1f} x host {host:.2f} "
                  f"/ 1.5)")
            if rec["runtime_mb_per_sec"] < floor:
                failures.append(
                    f"{rec['label']}: fused mb/s regressed >1.5x "
                    f"({rec['runtime_mb_per_sec']:.1f} < {floor:.1f})")
    wire = bench_wire()
    print_wire(wire)
    for codec, c in wire["codecs"].items():
        # both gates are ratios/deltas of in-run quantities —
        # host-independent
        if c["loss_delta"] > WIRE_LOSS_DELTA_MAX[codec]:
            failures.append(
                f"wire[{codec}]: loss delta {c['loss_delta']:.4f} > "
                f"ceiling {WIRE_LOSS_DELTA_MAX[codec]} — encode/decode "
                f"fidelity broke")
        if c["wire_bytes_reduction"] < WIRE_BYTES_REDUCTION_MIN[codec]:
            failures.append(
                f"wire[{codec}]: bytes reduction "
                f"{c['wire_bytes_reduction']:.2f}x < "
                f"{WIRE_BYTES_REDUCTION_MIN[codec]}x — codec not applied "
                f"to the boundary transfers")
    byz = bench_byzantine()
    print_byzantine(byz)
    # all three byzantine gates compare quantities from the same run —
    # host-independent
    if byz["loss_delta_defended"] >= BYZ_LOSS_DELTA_CEILING:
        failures.append(
            f"byzantine: defended end-loss delta "
            f"{byz['loss_delta_defended']:.4f} >= ceiling "
            f"{BYZ_LOSS_DELTA_CEILING} — the gradient screen no longer "
            f"contains a 10% corrupt fleet")
    if byz["loss_delta_undefended"] <= BYZ_LOSS_DELTA_CEILING:
        failures.append(
            f"byzantine: undefended end-loss delta "
            f"{byz['loss_delta_undefended']:.4f} <= ceiling "
            f"{BYZ_LOSS_DELTA_CEILING} — the adversary stopped hurting, "
            f"the defended gate is vacuous")
    if byz["detections"] == 0 or not byz["quarantined_during_run"]:
        failures.append(
            f"byzantine: screen detections={byz['detections']}, "
            f"quarantined={byz['quarantined_during_run']} — detection or "
            f"the reputation/quarantine hand-off broke")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("smoke OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + regression gate vs committed JSON")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke(args.out)

    print("== bench_exec: fused staged runtime vs remat oracle vs frozen "
          "per-microbatch full-jit reference ==")
    results = [bench_row(*row) for row in FULL_ROWS]
    for r in results:
        print_row(r)
    smoke_results = [bench_row(*row) for row in SMOKE_ROWS]
    print("-- smoke sizes (CI gate baseline) --")
    for r in smoke_results:
        print_row(r)
    wire = bench_wire()
    print_wire(wire)
    byz = bench_byzantine()
    print_byzantine(byz)
    recovery = bench_recovery()
    print(f"-- recovery: residual replay "
          f"{recovery['stage_replay_residual_ms']:.1f} ms vs remat replay "
          f"{recovery['stage_replay_remat_ms']:.1f} ms vs full pipeline "
          f"{recovery['full_pipeline_ms']:.1f} ms "
          f"({recovery['full_over_residual']:.1f}x) --")
    out = dict(
        meta=dict(
            seed=SEED, iterations=ITERATIONS,
            metric="completed microbatches per second of iteration wall "
                   "time (compile excluded), churn 0; fused = default "
                   "residual-carrying dispatch, remat = in-engine oracle "
                   "(backward re-runs the forward), int8 = fused with the "
                   "per-tensor symmetric int8(+fp32 scale) activation/"
                   "residual codec (non-gating); reference = frozen "
                   "pre-refactor per-microbatch whole-model-jit executor "
                   "(repro.core.runtime.reference) on identical seeded "
                   "iterations; resident_act_bytes = high-water encoded "
                   "store bytes (boundaries + residuals); int8_loss_delta "
                   "= |end-of-run loss(int8) - loss(fp)| on the same "
                   "seeded run; wire = forced inter-stage wire codecs "
                   "(bf16/int8/top-k on boundary-chunk transfers, forward "
                   "path only) with per-codec encoded bytes and end-of-run "
                   "loss delta vs the exact fp32 wire; byzantine = the "
                   "same seeded run clean / corrupt+screen-off / "
                   "corrupt+screen-on with end-of-run loss deltas vs "
                   "clean; recovery = per-crashed-microbatch repair "
                   "cost.  Measured on a 1-core CPU host: per-stage "
                   "dispatch chunking (auto_chunk, <=4 microbatches) "
                   "keeps residuals cache-hot, so absolute speedups vs "
                   "the monolithic reference are conservative here; "
                   "speedup_vs_remat is the host-stable fused-dispatch "
                   "win and is what the compute-bound smoke gate pins."),
        results=results,
        smoke_results=smoke_results,
        wire=wire,
        byzantine=byz,
        recovery=recovery)
    args.out.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, str(REPO_ROOT / "src"))
    sys.exit(main())
