"""Real-compute executor benchmark: staged runtime vs frozen full-jit.

Runs the same seeded churn-free training iterations (reduced 300M
family config) through

* the **staged runtime** (`repro.core.runtime`): per-stage jitted
  ``jax.vjp`` dispatches with same-stage microbatch stacking — B
  microbatches cost one dispatch per stage (plus the VJP's forward
  rematerialisation from the stored input activation, the price of
  stage-local recovery);
* the **frozen reference** (`repro.core.runtime.reference`): the
  pre-refactor executor, one whole-model ``value_and_grad`` dispatch
  per microbatch,

and measures **microbatches/sec** (completed microbatches per second
of iteration wall time, compile excluded).  The headline row is the
dispatch-bound regime (seq 32, microbatch size 1), where stacking wins
big; longer-sequence rows are recorded too so the compute-bound
crossover (where the remat overhead eats the stacking win) stays
visible.

It also measures **recovery cost**: the wall time of repairing one
backward crash stage-locally (one single-microbatch stage-VJP replay
from the stored activation, the paper's Sec. V-D repair) vs the
full-pipeline recompute a restart-based scheduler pays (one whole-model
forward+backward for the microbatch).

Results go to ``BENCH_exec.json``.  ``--smoke`` runs the small size
only and gates against the committed JSON: it exits non-zero if the
staged runtime's microbatches/sec regressed past the host-normalized
floor (committed value scaled by the reference's in-run speed, halved)
or if the batched-vs-reference speedup fell below 2x on the headline
configuration.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_exec.json"

SEED = 0
ITERATIONS = 3

# (label, layers, d_model, seq_len, microbatch, num_microbatches, stages)
FULL_ROWS = [
    ("dispatch_bound", 4, 128, 32, 1, 32, 4),   # headline: >= 2x gated
    ("mixed", 4, 128, 64, 1, 32, 4),
    ("compute_bound", 4, 128, 128, 1, 32, 4),
]
SMOKE_ROWS = [("dispatch_bound", 2, 128, 32, 1, 16, 2)]


def _build(label, layers, d_model, seq, mbsz, n_mb, stages):
    from repro.configs import get_config
    from repro.core.flow.graph import geo_distributed_network
    from repro.data.pipeline import DataConfig, DataNodeShard

    cfg = dataclasses.replace(
        get_config("gwtf-llama-300m").reduced(num_layers=layers,
                                              d_model=d_model),
        vocab_size=512)

    def make_net():
        return geo_distributed_network(
            num_stages=stages, relay_capacities=[16] * (3 * stages),
            num_data_nodes=1, data_capacity=n_mb,
            rng=np.random.default_rng(SEED))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                    batch_size=n_mb * mbsz, microbatch_size=mbsz, seed=SEED)
    mbs = DataNodeShard(dc, 0, 1).microbatches()
    return cfg, make_net, mbs


def _throughput(trainer, mbs, iterations=ITERATIONS):
    dn = 0
    trainer.iteration({dn: mbs})           # compile + warm caches
    t0 = time.perf_counter()
    done = 0
    for _ in range(iterations):
        r = trainer.iteration({dn: mbs})
        done += r.completed
    dt = time.perf_counter() - t0
    return done / dt, done


def bench_row(label, layers, d_model, seq, mbsz, n_mb, stages) -> dict:
    from repro.core.runtime.reference import ReferenceDecentralizedTrainer
    from repro.core.runtime.trainer import RuntimeTrainer
    from repro.core.sim.faults import TraceChurn

    cfg, make_net, mbs = _build(label, layers, d_model, seq, mbsz, n_mb,
                                stages)
    rt = RuntimeTrainer(cfg, make_net(), lr=1e-3, seed=SEED,
                        churn_model=TraceChurn([]))
    rt_mbs, rt_done = _throughput(rt, mbs)
    ref = ReferenceDecentralizedTrainer(cfg, make_net(), churn=0.0,
                                        lr=1e-3, seed=SEED)
    ref_mbs, ref_done = _throughput(ref, mbs)
    return dict(
        label=label, layers=layers, d_model=d_model, seq_len=seq,
        microbatch=mbsz, num_microbatches=n_mb, stages=stages,
        runtime_mb_per_sec=round(rt_mbs, 2),
        reference_mb_per_sec=round(ref_mbs, 2),
        speedup=round(rt_mbs / ref_mbs, 2),
        completed=(rt_done, ref_done),
    )


def bench_recovery(layers=4, d_model=128, seq=64, stages=4) -> dict:
    """Stage-local repair vs full-pipeline recompute, per crashed
    microbatch: one stage-VJP replay from the stored activation
    (GWTF, Sec. V-D) against one whole-model fwd+bwd (restart-based
    recovery)."""
    import jax
    import jax.numpy as jnp

    from repro.core.runtime.stages import (StageCompute, embed_fn,
                                           init_head_params,
                                           init_stage_params, loss_fn,
                                           stage_forward)
    from repro.configs import get_config

    cfg = dataclasses.replace(
        get_config("gwtf-llama-300m").reduced(num_layers=layers,
                                              d_model=d_model),
        vocab_size=512)
    key = jax.random.PRNGKey(SEED)
    stage_params = [init_stage_params(cfg, s, stages, key)
                    for s in range(stages)]
    head = init_head_params(cfg, jax.random.fold_in(key, 999))
    sc = StageCompute(cfg, stages)
    rng = np.random.default_rng(SEED)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)))
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)))
    x = sc.embed(head, tokens)

    def full(head_p, stage_ps, toks, labs):
        h = embed_fn(head_p, toks)
        for s in range(stages):
            h = stage_forward(stage_ps[s], h, cfg)
        return loss_fn(head_p, h, labs, cfg)

    full_grad = jax.jit(jax.value_and_grad(full, argnums=(0, 1)))

    def timed(fn, reps=20):
        fn()                                   # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return (time.perf_counter() - t0) / reps

    # a fresh cotangent per call: the backward dispatch donates its
    # cotangent buffer on GPU/TPU, so reusing `g` would crash there
    stage_ms = timed(lambda: jax.block_until_ready(
        sc.backward(0, stage_params[0], x, jnp.ones_like(x)))) * 1e3
    full_ms = timed(lambda: jax.block_until_ready(
        full_grad(head, stage_params, tokens, labels))) * 1e3
    return dict(layers=layers, d_model=d_model, seq_len=seq, stages=stages,
                stage_replay_ms=round(stage_ms, 3),
                full_pipeline_ms=round(full_ms, 3),
                full_over_stage=round(full_ms / stage_ms, 2))


def print_row(r: dict):
    print(f"  {r['label']:15s} L{r['layers']} d{r['d_model']} "
          f"seq{r['seq_len']:4d} mb{r['microbatch']}x"
          f"{r['num_microbatches']:3d} S{r['stages']}: "
          f"runtime {r['runtime_mb_per_sec']:8.1f} mb/s  "
          f"reference {r['reference_mb_per_sec']:8.1f} mb/s  "
          f"speedup {r['speedup']:.2f}x")


def smoke(committed_path: Path) -> int:
    """CI gate: fail if the staged runtime regressed past the
    host-normalized floor or the headline speedup dropped below 2x."""
    committed = {}
    if committed_path.exists():
        data = json.loads(committed_path.read_text())
        committed = {r["label"]: r for r in data.get("smoke_results", [])}
    else:
        print(f"no committed {committed_path.name}; smoke run is "
              f"informational only")
    failures = []
    print("== bench_exec --smoke ==")
    for row in SMOKE_ROWS:
        rec = bench_row(*row)
        print_row(rec)
        if rec["speedup"] < 2.0:
            failures.append(
                f"{rec['label']}: batched runtime speedup "
                f"{rec['speedup']:.2f}x < 2x over the per-microbatch "
                f"full-jit reference")
        base = committed.get(rec["label"])
        if base is not None:
            host = rec["reference_mb_per_sec"] / base["reference_mb_per_sec"]
            floor = base["runtime_mb_per_sec"] * host / 2.0
            print(f"    gate: measured {rec['runtime_mb_per_sec']:.1f} mb/s "
                  f"vs floor {floor:.1f} mb/s (committed "
                  f"{base['runtime_mb_per_sec']:.1f} x host {host:.2f} / 2)")
            if rec["runtime_mb_per_sec"] < floor:
                failures.append(
                    f"{rec['label']}: runtime mb/s regressed >2x "
                    f"({rec['runtime_mb_per_sec']:.1f} < {floor:.1f})")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("smoke OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small size + regression gate vs committed JSON")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke(args.out)

    print("== bench_exec: staged runtime vs frozen per-microbatch "
          "full-jit reference ==")
    results = [bench_row(*row) for row in FULL_ROWS]
    for r in results:
        print_row(r)
    smoke_results = [bench_row(*row) for row in SMOKE_ROWS]
    print("-- smoke size (CI gate baseline) --")
    for r in smoke_results:
        print_row(r)
    recovery = bench_recovery()
    print(f"-- recovery: stage replay {recovery['stage_replay_ms']:.1f} ms "
          f"vs full pipeline {recovery['full_pipeline_ms']:.1f} ms "
          f"({recovery['full_over_stage']:.1f}x) --")
    out = dict(
        meta=dict(
            seed=SEED, iterations=ITERATIONS,
            metric="completed microbatches per second of iteration wall "
                   "time (compile excluded), churn 0; reference = frozen "
                   "pre-refactor per-microbatch whole-model-jit executor "
                   "(repro.core.runtime.reference) on identical seeded "
                   "iterations; recovery = per-crashed-microbatch repair "
                   "cost, stage-local VJP replay vs whole-model rerun"),
        results=results,
        smoke_results=smoke_results,
        recovery=recovery)
    args.out.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, str(REPO_ROOT / "src"))
    sys.exit(main())
