"""Node-count scaling benchmark for the GWTF flow engine.

For growing relay counts (default 100 -> 2000, 10 stages) this measures:

* **rounds/sec** of the indexed ``GWTFProtocol`` over a full convergence
  run (``run(max_rounds=200)``, default quiet window) — the headline
  metric the CI smoke gate defends;
* **rounds/sec of the pre-optimization reference implementation**
  (``ReferenceGWTFProtocol``) executing the *identical* rounds on the
  same seed — the two engines are behavior-equivalent, so this is a
  like-for-like measurement of the indexing speedup;
* **time-to-convergence** (init + rounds, wall seconds);
* **solution quality vs. the centralized min-cost max-flow optimum**
  (sum-of-edge-costs ratio at the same flow value);
* **hierarchical vs. flat planning** on a paper-style geo topology
  (10 locations, per-location-pair base latency + node jitter,
  ``Node.location`` stamped): wall time and cost of
  ``solve_hierarchical`` against the flat dial MCMF oracle at the same
  flow value.  The gap is deterministic (seeded, host-independent), so
  ``hier_gap_bound`` in the committed JSON is an exact gate.  Above
  ``--optimal-max`` relays the flat oracle is skipped (it is the
  quadratic cost the hierarchy exists to avoid) and only the
  hierarchical planning time is recorded — this is how the
  ``--relays 10000`` row stays tractable.

Results are written to ``BENCH_scale.json`` at the repo root so future
PRs have a perf trajectory to defend.

``--smoke`` runs the small sizes only and compares against the committed
``BENCH_scale.json``: it exits non-zero if the optimized engine's
rounds/sec regressed by more than 2x, or if the hierarchical planner's
optimality gap exceeds the committed bound.  To keep the time gate
meaningful on slower CI hosts, the comparison is normalized by the
reference engine's rounds/sec measured in the same run (the reference
is the host-speed calibration: a uniformly slower machine slows both
engines).

This module deliberately avoids the jax-importing benchmark helpers —
it needs only numpy, so the CI smoke job stays light.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork, Node, synthetic_network
from repro.core.flow.hierarchy import solve_hierarchical
from repro.core.flow.mincost import solve_training_flow
from repro.core.flow.reference import ReferenceGWTFProtocol

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_scale.json"

STAGES = 10
SOURCES = 2
SEED = 0
LOCATIONS = 10
HIER_GAP_BOUND = 1.15   # committed optimality-gap bound (deterministic)
FULL_SIZES = (100, 200, 500, 1000, 2000, 10000)
SMOKE_SIZES = (100, 200)


def build_network(relays: int, seed: int = SEED):
    """Table-V-style abstract network scaled up: d_ij ~ U{1..19},
    caps ~ U{1..3}, source capacity growing with the swarm."""
    rng = np.random.default_rng(seed)

    def link_costs(r, size=None):
        if size is not None:                 # vectorized fast path
            return np.floor(r.uniform(1, 20, size=size))
        return float(int(r.uniform(1, 20)))

    return synthetic_network(
        num_stages=STAGES, relays_per_stage=relays // STAGES,
        capacities=lambda r: int(r.uniform(1, 4)),
        link_costs=link_costs,
        num_sources=SOURCES, source_capacity=max(4, relays // 20),
        rng=rng)


def build_geo_network(relays: int, seed: int = SEED):
    """Paper-style geo topology (Sec. VI): ``LOCATIONS`` locations with
    per-location-pair base latency ~U{4..20} (intra ~U{1..4}) plus
    symmetric per-node-pair jitter ~U{0..2}; ``Node.location`` stamped
    so the hierarchical planner can aggregate."""
    rng = np.random.default_rng(seed)
    N = relays + SOURCES
    nodes, loc = {}, np.empty(N, np.int64)
    for d in range(SOURCES):
        nodes[d] = Node(d, -1, max(4, relays // 20), 0.0, is_data=True)
        loc[d] = int(rng.integers(0, LOCATIONS))
    for i in range(relays):
        nid = SOURCES + i
        nodes[nid] = Node(nid, i % STAGES, int(rng.integers(1, 4)), 0.0,
                          location=int(rng.integers(0, LOCATIONS)))
        loc[nid] = nodes[nid].location
    base = rng.integers(4, 21, (LOCATIONS, LOCATIONS)).astype(float)
    base = np.maximum(base, base.T)
    np.fill_diagonal(base, 0.0)
    base += np.diag(rng.integers(1, 5, LOCATIONS).astype(float))
    jitter = rng.integers(0, 3, (N, N)).astype(float)
    cm = base[np.ix_(loc, loc)] + np.maximum(jitter, jitter.T)
    np.fill_diagonal(cm, 0.0)
    net = FlowNetwork(nodes=nodes, num_stages=STAGES, latency=cm,
                      bandwidth=np.full((N, N), np.inf),
                      activation_size=0.0)
    return net, cm


def bench_geo(relays: int, *, flat: bool, seed: int = SEED) -> dict:
    """Hierarchy-on vs. hierarchy-off planning columns (geo topology)."""
    net, cost = build_geo_network(relays, seed)
    rec = {}
    t0 = time.perf_counter()
    h = solve_hierarchical(net, cost_matrix=cost)
    rec["hier_s"] = round(time.perf_counter() - t0, 4)
    rec["hier_cost"] = h.cost
    rec["hier_flow"] = h.flow
    rec["hier_regions"] = h.num_regions
    if flat:
        t0 = time.perf_counter()
        plan = solve_training_flow(net, cost_matrix=cost,
                                   max_flow=h.flow, method="dial")
        rec["geo_flat_s"] = round(time.perf_counter() - t0, 4)
        rec["geo_flat_cost"] = plan.cost
        if plan.cost > 0 and plan.flow >= h.flow:
            rec["hier_gap"] = round(h.cost / plan.cost, 4)
            rec["hier_speedup"] = round(rec["geo_flat_s"]
                                        / max(rec["hier_s"], 1e-9), 2)
    return rec


def bench_size(relays: int, *, baseline: bool, optimal: bool,
               seed: int = SEED) -> dict:
    t0 = time.perf_counter()
    net, cost = build_network(relays, seed)
    build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                         rng=np.random.default_rng(seed + 3))
    init_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rounds = proto.run(max_rounds=200)
    run_s = time.perf_counter() - t0
    flows = proto.complete_flows()
    rec = dict(
        relays=relays, stages=STAGES, nodes=len(net.nodes),
        rounds=rounds, flows=len(flows),
        build_s=round(build_s, 4), init_s=round(init_s, 4),
        run_s=round(run_s, 4),
        convergence_s=round(init_s + run_s, 4),
        rounds_per_sec=round(rounds / run_s, 3),
        total_cost=proto.total_cost(),
        max_edge_cost=proto.max_edge_cost(),
    )

    if baseline:
        net_r, cost_r = build_network(relays, seed)
        ref = ReferenceGWTFProtocol(net_r, cost_matrix=cost_r,
                                    objective="sum",
                                    rng=np.random.default_rng(seed + 3))
        t0 = time.perf_counter()
        for _ in range(rounds):
            ref.step_round()
        ref_s = time.perf_counter() - t0
        rec["ref_rounds_per_sec"] = round(rounds / ref_s, 3)
        rec["speedup_vs_reference"] = round(ref_s / run_s, 2)
        rec["flows_match_reference"] = flows == ref.complete_flows()

    if optimal:
        t0 = time.perf_counter()
        plan = solve_training_flow(net, cost_matrix=cost,
                                   max_flow=max(len(flows), 1))
        rec["optimal_s"] = round(time.perf_counter() - t0, 4)
        rec["optimal_cost"] = plan.cost
        if plan.cost > 0:
            rec["cost_ratio_vs_optimal"] = round(proto.total_cost()
                                                 / plan.cost, 4)
    return rec


def print_row(rec: dict):
    ref = rec.get("ref_rounds_per_sec")
    spd = rec.get("speedup_vs_reference")
    ratio = rec.get("cost_ratio_vs_optimal")
    print(f"  relays={rec['relays']:5d}  rounds={rec['rounds']:3d}  "
          f"opt={rec['rounds_per_sec']:8.2f} r/s  "
          f"ref={ref if ref is not None else '   n/a':>8} r/s  "
          f"speedup={spd if spd is not None else 'n/a':>5}x  "
          f"conv={rec['convergence_s']:7.2f}s  "
          f"vs-optimal={ratio if ratio is not None else 'n/a'}")
    if "hier_s" in rec:
        flat_s = rec.get("geo_flat_s")
        gap = rec.get("hier_gap")
        print(f"    geo: hier={rec['hier_s']:7.2f}s  "
              f"flat={flat_s if flat_s is not None else 'n/a (skipped)':>7}"
              f"{'s' if flat_s is not None else ''}  "
              f"gap={gap if gap is not None else 'n/a'}  "
              f"regions={rec['hier_regions']}  "
              f"flow={rec['hier_flow']:.0f}")


def smoke(committed_path: Path) -> int:
    """CI gate: fail (exit 1) if rounds/sec regressed > 2x vs committed,
    normalized by the reference engine's speed on this host."""
    if not committed_path.exists():
        print(f"no committed {committed_path.name}; smoke run is "
              f"informational only")
        committed = {}
    else:
        data = json.loads(committed_path.read_text())
        committed = {r["relays"]: r for r in data["results"]}
    if committed_path.exists():
        gap_bound = json.loads(committed_path.read_text())["meta"].get(
            "hier_gap_bound", HIER_GAP_BOUND)
    else:
        gap_bound = HIER_GAP_BOUND
    failures = []
    print(f"== bench_scale --smoke (sizes {SMOKE_SIZES}) ==")
    for relays in SMOKE_SIZES:
        rec = bench_size(relays, baseline=True, optimal=False)
        rec.update(bench_geo(relays, flat=True))
        print_row(rec)
        gap = rec.get("hier_gap")
        if gap is not None and gap > gap_bound:
            failures.append(f"relays={relays}: hierarchical gap {gap} "
                            f"exceeds committed bound {gap_bound}")
        elif gap is None:
            failures.append(f"relays={relays}: hierarchical planner did "
                            f"not reach the oracle's flow value")
        if not rec.get("flows_match_reference", True):
            failures.append(f"relays={relays}: optimized flows diverged "
                            f"from reference")
            continue
        base = committed.get(relays)
        if base is None or "ref_rounds_per_sec" not in base:
            continue
        host_factor = rec["ref_rounds_per_sec"] / base["ref_rounds_per_sec"]
        floor = base["rounds_per_sec"] * host_factor / 2.0
        print(f"    gate: measured {rec['rounds_per_sec']:.2f} r/s vs "
              f"floor {floor:.2f} r/s "
              f"(committed {base['rounds_per_sec']:.2f} x host "
              f"{host_factor:.2f} / 2)")
        if rec["rounds_per_sec"] < floor:
            failures.append(
                f"relays={relays}: rounds/sec regressed >2x "
                f"({rec['rounds_per_sec']:.2f} < floor {floor:.2f})")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("smoke OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + regression gate vs committed JSON")
    ap.add_argument("--sizes", "--relays", type=int, nargs="*", default=None,
                    dest="sizes",
                    help="relay-count sweep (e.g. --relays 500 1000 2000)")
    ap.add_argument("--baseline-max", type=int, default=2000,
                    help="largest size at which the reference baseline runs")
    ap.add_argument("--optimal-max", type=int, default=2000,
                    help="largest size at which the exact MCMF oracle runs "
                         "(flat geo planning obeys the same cap)")
    ap.add_argument("--no-optimal", action="store_true")
    ap.add_argument("--no-hierarchy", action="store_true",
                    help="skip the geo hierarchy-on/off columns")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke(args.out)

    sizes = tuple(args.sizes) if args.sizes else FULL_SIZES
    print(f"== bench_scale: {STAGES} stages, {SOURCES} sources, "
          f"sizes {sizes} ==")
    results = []
    for relays in sizes:
        rec = bench_size(relays, baseline=relays <= args.baseline_max,
                         optimal=(not args.no_optimal
                                  and relays <= args.optimal_max))
        if not args.no_hierarchy:
            rec.update(bench_geo(relays, flat=relays <= args.optimal_max))
        print_row(rec)
        results.append(rec)
    out = dict(
        meta=dict(stages=STAGES, sources=SOURCES, seed=SEED,
                  locations=LOCATIONS, hier_gap_bound=HIER_GAP_BOUND,
                  objective="sum", max_rounds=200, quiet_rounds=25,
                  metric="rounds_per_sec over a full convergence run; "
                         "reference = pre-optimization implementation "
                         "(repro.core.flow.reference) on identical rounds; "
                         "hier_* = solve_hierarchical vs flat dial MCMF "
                         "on the geo topology (build_geo_network)"),
        results=results)
    args.out.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
