"""Serving-plane benchmark: tail latency under churn, defended vs not.

Every committed serving corpus scenario (`spec.has_arrivals`) is run
through the numpy serving simulator in three configurations:

* **churn0** — the same arrival program with churn stripped (the
  undisturbed baseline),
* **defended** — the spec's churn program (plus the overlay below),
  with the requeue-instead-of-drop defense on: interrupted requests
  migrate their KV cache to a surviving chain and re-prefill only the
  crashed stage's slice,
* **undefended** — identical churn, `reroute=False`: the classic
  drop-and-retry serving baseline that restarts a victim request from
  scratch.

All latency numbers are **simulated seconds** — a deterministic
function of the spec and seed, bit-identical across hosts — so the
``--smoke`` CI gate needs no host normalization: it requires the
defended tail metrics to match the committed JSON *exactly* and pins
the defended-vs-undefended p99-TTFT ratio at >= 2x on the scenarios
whose churn interrupts requests mid-decode.  (``serve-steady-poisson``
is kept ungated on purpose: its short decode means the crash lands
during *prefill*, the k=0 regime where requeue buys nothing over a
restart — the honest boundary of the defense.)  Wall-clock columns are
informational only.

``--json PATH`` (default ``BENCH_serve.json``) writes the table.
Numpy-only; never imports JAX.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

from repro.core.scenarios import generate
from repro.core.scenarios.corpus import load_corpus
from repro.core.sim.metrics import summarize_serving

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_serve.json"

#: churn overlays: make every scenario's churn variant actually fault a
#: planned serving chain (crash nodes chosen on the seed's chain plans).
CHURN_OVERLAYS = {
    "serve-steady-poisson": [
        {"kind": "trace", "events": [(0, "crash", 6, 0.7)]}],
    "serve-flash-spike": [
        {"kind": "flash_crowd", "at_iteration": 1, "nodes": 2},
        {"kind": "trace", "events": [(1, "crash", 5, 0.5)]}],
}

#: scenarios whose churn interrupts requests mid-decode (k > 0) / mid
#: assignment — where the requeue defense must beat drop-and-retry.
GATED = ("serve-flash-spike", "serve-churn-under-load")
RATIO_FLOOR = 2.0


def _tails(ms) -> dict:
    s = summarize_serving(ms)
    return {k: round(s[k], 4) for k in
            ("p50_ttft", "p99_ttft", "p50_tpot", "p99_tpot",
             "admitted", "completed", "dropped", "requeues", "restarts",
             "migrated_kv_bytes")}


def _run(spec, **kw) -> dict:
    t0 = time.perf_counter()
    eng = generate.build_serving_sim(spec, **kw)
    ms = eng.run(spec.iterations)
    row = _tails(ms)
    row["wall_s"] = round(time.perf_counter() - t0, 4)
    return row


def bench_scenario(spec) -> dict:
    churn = dataclasses.replace(
        spec, churn=CHURN_OVERLAYS.get(spec.name, spec.churn))
    churn.validate()
    crashed = {e[2] for c in churn.churn if c["kind"] == "trace"
               for e in c["events"] if e[1] == "crash"}
    nodes = spec.base_nodes + spec.spare_nodes
    row = {
        "name": spec.name,
        "nodes": nodes,
        "gen_tokens": spec.gen_tokens,
        "churn_frac": round(len(crashed) / nodes, 4),
        "churn0": _run(dataclasses.replace(spec, churn=[])),
        "defended": _run(churn),
        "undefended": _run(churn, reroute=False),
    }
    row["p99_ttft_ratio"] = round(
        row["undefended"]["p99_ttft"]
        / max(row["defended"]["p99_ttft"], 1e-9), 4)
    return row


def run_sweep() -> list:
    rows = []
    hdr = (f"{'scenario':24s} {'nodes':>5s} {'churn%':>6s} "
           f"{'p99ttft@0':>9s} {'def p99':>8s} {'und p99':>8s} "
           f"{'ratio':>6s} {'rq':>4s} {'rs':>4s}")
    print(hdr)
    print("-" * len(hdr))
    for spec in load_corpus():
        if not spec.has_arrivals:
            continue
        r = bench_scenario(spec)
        rows.append(r)
        print(f"{r['name']:24s} {r['nodes']:5d} "
              f"{100 * r['churn_frac']:6.1f} "
              f"{r['churn0']['p99_ttft']:9.2f} "
              f"{r['defended']['p99_ttft']:8.2f} "
              f"{r['undefended']['p99_ttft']:8.2f} "
              f"{r['p99_ttft_ratio']:6.2f} "
              f"{r['defended']['requeues']:4.0f} "
              f"{r['undefended']['restarts']:4.0f}")
    return rows


def _payload(rows) -> dict:
    return {
        "meta": {
            "metric": ("simulated-seconds TTFT/TPOT tails from the "
                       "serving event simulator; defended = requeue + "
                       "KV migration, undefended = drop-and-retry "
                       "(reroute=False); deterministic per spec seed"),
            "ratio_floor": RATIO_FLOOR,
            "gated": list(GATED),
        },
        "results": rows,
    }


def smoke(committed_path: Path) -> int:
    """CI gate: simulated tails must match the committed JSON exactly
    (they are host-independent), and on every gated scenario the
    defended p99 TTFT must stay >= RATIO_FLOOR x better than the
    undefended drop-and-retry baseline."""
    rows = run_sweep()
    failures = []
    committed = {}
    floor = RATIO_FLOOR
    if committed_path.exists():
        data = json.loads(committed_path.read_text())
        committed = {r["name"]: r for r in data["results"]}
        floor = data["meta"].get("ratio_floor", RATIO_FLOOR)
    else:
        print(f"no committed {committed_path.name}; ratio gate only")
    for r in rows:
        name = r["name"]
        if name in GATED and r["p99_ttft_ratio"] < floor:
            failures.append(
                f"{name}: defended p99 TTFT advantage "
                f"{r['p99_ttft_ratio']:.2f}x under churn fell below the "
                f"pinned {floor}x floor")
        base = committed.get(name)
        if base is None:
            continue
        for variant in ("churn0", "defended", "undefended"):
            got = dict(r[variant])
            want = dict(base[variant])
            got.pop("wall_s", None)
            want.pop("wall_s", None)
            if got != want:
                failures.append(
                    f"{name}/{variant}: simulated serving tails diverged "
                    f"from committed {committed_path.name} "
                    f"(got {got}, committed {want})")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(" -", f)
        return 1
    print(f"smoke ok: {len(rows)} scenarios, gated {list(GATED)} "
          f">= {floor}x")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", type=Path, default=DEFAULT_OUT,
                    help="write the table to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate against the committed JSON; writes "
                         "nothing")
    args = ap.parse_args(argv)
    if args.smoke:
        return smoke(DEFAULT_OUT)
    rows = run_sweep()
    args.json.write_text(json.dumps(_payload(rows), indent=2) + "\n")
    print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
