"""Paper Table II: crash-prone training of the LLaMA-like model.

GWTF vs SWARM, homogeneous/heterogeneous capacities x {0, 10, 20}% churn.
Reported: time per microbatch (min), throughput (#mb/iteration),
communication time, wasted GPU time.  Target claims: up to 45% training-
time reduction in heterogeneous churn settings; wasted GPU time ~0.

``--runtime`` additionally runs one real-compute row through the staged
runtime (`repro.core.runtime`): the same crash-prone scenario executed
with actual JAX compute, reporting microbatches/sec and the
reroute/stage-recompute counters alongside the simulated table.
"""
import argparse
import sys

from benchmarks.common import crash_table, csv_row, print_crash_table, \
    runtime_row


def run(reps: int = 5, iterations: int = 12, verbose: bool = True):
    rows = crash_table("gwtf-llama-300m", reps=reps, iterations=iterations)
    if verbose:
        print_crash_table("Table II — LLaMA-like, crash-prone", rows)
    out = []
    for r in rows:
        lab = f"tableII_{r['setting']}{int(r['churn']*100)}"
        s = r["swarm"]["time_per_mb_min"][0]
        g = r["gwtf"]["time_per_mb_min"][0]
        red = (s - g) / s if s else 0.0
        out.append(csv_row(f"{lab}_time_reduction", red,
                           f"swarm={s:.2f}min gwtf={g:.2f}min"))
        out.append(csv_row(f"{lab}_gwtf_waste_min",
                           r["gwtf"]["wasted_min"][0],
                           f"swarm_waste={r['swarm']['wasted_min'][0]:.2f}"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--runtime", action="store_true",
                    help="also run one real-compute row through the "
                         "staged runtime")
    ap.add_argument("--activation-codec", choices=["fp", "int8"],
                    default="fp",
                    help="activation/residual store codec for the "
                         "--runtime row")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--iterations", type=int, default=12)
    args = ap.parse_args(argv)
    for line in run(reps=args.reps, iterations=args.iterations):
        print(line)
    if args.runtime:
        r = runtime_row("gwtf-llama-300m",
                        activation_codec=args.activation_codec)
        print(csv_row("tableII_runtime_mb_per_sec", r["mb_per_sec"],
                      f"rerouted={r['rerouted']} "
                      f"recomputes={r['stage_recomputes']} "
                      f"store={r['store_peak_bytes'] / 1e6:.1f}MB"
                      f"({r['activation_codec']})"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
