"""Paper Table II: crash-prone training of the LLaMA-like model.

GWTF vs SWARM, homogeneous/heterogeneous capacities x {0, 10, 20}% churn.
Reported: time per microbatch (min), throughput (#mb/iteration),
communication time, wasted GPU time.  Target claims: up to 45% training-
time reduction in heterogeneous churn settings; wasted GPU time ~0.
"""
from benchmarks.common import crash_table, csv_row, print_crash_table


def run(reps: int = 5, iterations: int = 12, verbose: bool = True):
    rows = crash_table("gwtf-llama-300m", reps=reps, iterations=iterations)
    if verbose:
        print_crash_table("Table II — LLaMA-like, crash-prone", rows)
    out = []
    for r in rows:
        lab = f"tableII_{r['setting']}{int(r['churn']*100)}"
        s = r["swarm"]["time_per_mb_min"][0]
        g = r["gwtf"]["time_per_mb_min"][0]
        red = (s - g) / s if s else 0.0
        out.append(csv_row(f"{lab}_time_reduction", red,
                           f"swarm={s:.2f}min gwtf={g:.2f}min"))
        out.append(csv_row(f"{lab}_gwtf_waste_min",
                           r["gwtf"]["wasted_min"][0],
                           f"swarm_waste={r['swarm']['wasted_min'][0]:.2f}"))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
