"""Paper Fig. 5: node-addition policies on the Table IV settings.

Iteratively add 20 candidate nodes; measure flow-cost improvement
(cost_before - cost_after) / cost_before under four policies:
  gwtf (bottleneck-utilization), capacity-first, random, optimal
(optimal = per-addition exhaustive candidate x stage search via the
out-of-kilter-equivalent min-cost-flow oracle).

Paper claims: GWTF > capacity-first (up to 1.5x) > random (up to 3.5x),
never more than 25% behind optimal.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.flow.graph import FlowNetwork, Node, synthetic_network
from repro.core.flow.mincost import solve_training_flow
from repro.core.join import StageReport, assign_joiners

# Table IV (top): stages, capacities, interlayer costs
SETTINGS = [
    dict(name="1", stages=8, cap=(1, 20), inter=(1, 100)),
    dict(name="2", stages=8, cap=(1, 20), inter=(20, 100)),
    dict(name="3", stages=8, cap=(1, 5), inter=(1, 100)),
    dict(name="4", stages=12, cap=(1, 20), inter=(1, 100)),
    dict(name="5*", stages=8, cap=(1, 20), inter=(1, 100), uneven=True),
]
TOTAL_NODES = 96       # 97 minus 1 dataholder
NUM_JOINERS = 20


def build_setting(s, seed):
    rng = np.random.default_rng(seed)
    relays = TOTAL_NODES - NUM_JOINERS
    per_stage = relays // s["stages"]
    net, cost = synthetic_network(
        num_stages=s["stages"], relays_per_stage=per_stage,
        capacities=lambda r: int(r.uniform(*s["cap"])),
        link_costs=lambda r: float(int(r.uniform(*s["inter"]))),
        num_sources=1, source_capacity=10**6, rng=rng)
    if s.get("uneven"):
        # setting 5*: random number of nodes per stage — drop a random
        # ~25% of relays so stage sizes differ.
        relay_ids = [n.id for n in net.nodes.values() if not n.is_data]
        drop = rng.choice(relay_ids, size=len(relay_ids) // 4,
                          replace=False)
        for nid in drop:
            net.nodes[nid].alive = False
    # source capacity "sufficient to prevent bottlenecks"
    net.nodes[0].capacity = sum(n.capacity for n in net.stage_nodes(0))
    return net, cost, rng


def add_candidate(net: FlowNetwork, cost, stage: int, cap: int, rng,
                  inter):
    nid = max(net.nodes) + 1
    node = Node(nid, stage, cap, 0.0)
    N = len(net.nodes)
    row = np.array([float(int(rng.uniform(*inter))) for _ in range(N)])
    col = np.array([float(int(rng.uniform(*inter))) for _ in range(N)])
    size = N + 1
    new_cost = np.zeros((size, size))
    new_cost[:N, :N] = cost
    new_cost[N, :N] = row
    new_cost[:N, N] = col
    net.nodes[nid] = node
    # keep graph matrices in sync (unused for synthetic cost matrices)
    net.latency = new_cost
    return new_cost


def _iteration_time_proxy(net, cost) -> float:
    """(avg path cost) / throughput — flows run in parallel, so iteration
    time scales with per-path cost while each iteration delivers `flow`
    microbatches.  This is the metric the addition policies compete on
    (the paper reports flow-cost improvement; adding capacity at the
    bottleneck only pays off through throughput, which this captures)."""
    plan = solve_training_flow(net, cost_matrix=cost)
    if plan.flow <= 0:
        return float("inf")
    return (plan.cost / plan.flow) / plan.flow


def run_policy(s, policy: str, seed: int) -> float:
    net, cost, rng = build_setting(s, seed)
    crng = np.random.default_rng(seed + 1)
    cand_caps = [int(crng.uniform(*s["cap"])) for _ in range(NUM_JOINERS)]
    m_before = _iteration_time_proxy(net, cost)

    for cap in cand_caps:
        plan = solve_training_flow(net, cost_matrix=cost)
        reports = [StageReport(st, net.stage_capacity(st), int(plan.flow))
                   for st in range(net.num_stages)]
        if policy == "optimal":
            best_stage, best_m = 0, None
            for st in range(net.num_stages):
                trial_cost_m = add_candidate(net, cost, st, cap, crng,
                                             s["inter"])
                m = _iteration_time_proxy(net, trial_cost_m)
                # undo
                del net.nodes[max(net.nodes)]
                if best_m is None or m < best_m:
                    best_stage, best_m = st, m
            stage = best_stage
        else:
            stage = assign_joiners(reports, [cap], policy=policy,
                                   rng=crng)[0]
        cost = add_candidate(net, cost, stage, cap, crng, s["inter"])

    m_after = _iteration_time_proxy(net, cost)
    return (m_before - m_after) / m_before


def run(reps: int = 4, verbose: bool = True):
    out = []
    if verbose:
        print("\n=== Fig. 5 — node addition: avg cost improvement ===")
        print(f"{'setting':8s} {'gwtf':>7s} {'capacity':>9s} {'random':>7s} "
              f"{'optimal':>8s}")
    for s in SETTINGS:
        vals = {}
        for policy in ("gwtf", "capacity", "random", "optimal"):
            imp = [run_policy(s, policy, seed) for seed in range(reps)]
            vals[policy] = float(np.mean(imp))
        if verbose:
            print(f"{s['name']:8s} {vals['gwtf']:7.1%} "
                  f"{vals['capacity']:9.1%} {vals['random']:7.1%} "
                  f"{vals['optimal']:8.1%}")
        out.append(csv_row(f"fig5_setting{s['name']}_gwtf", vals["gwtf"],
                           f"cap={vals['capacity']:.3f} rnd={vals['random']:.3f} "
                           f"opt={vals['optimal']:.3f}"))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
