"""Simulator event-core benchmark: layered engine vs pre-refactor loop.

For growing relay counts (headline: 1000 relays / 10 stages) this runs
the *same* seeded churn iterations through both simulator
implementations and measures:

* **events/sec** — canonical calendar events per second of event-loop
  wall time.  The canonical event count is the pre-refactor loop's
  (one ARRIVE + one CHECK per send, one DONE per compute): both
  engines simulate exactly that event sequence, but the layered core
  materializes timeout (CHECK) records lazily — only when a
  microbatch actually stalls — so its own pop count is lower for the
  identical simulation.  Normalizing both engines by the canonical
  count makes events/sec a pure wall-time comparison of the same work;
  each engine's raw pop count is also recorded (``pops``).
* **loop-time speedup** — reference loop seconds / engine loop
  seconds over the identical iterations;
* **behavior equivalence** — on the GWTF scheduler the two
  implementations must produce bit-identical metrics (same RNG
  stream, same float arithmetic); SWARM is expected to differ
  slightly because the layered engine fixes the backward-restart slot
  leak, so only GWTF equivalence gates.

``--profile`` additionally reports the per-iteration planning vs
event-loop wall-time split and — on the GWTF scheduler — the online
dial-oracle optimality gap of every plan
(``IterationMetrics.cost_ratio_vs_optimal`` via
``GWTFPolicy(track_optimality=True)``; the oracle's wall time is
excluded from the engine's planning-overrun guard).

A separate **WAN compression record** (``wan`` key in the JSON) runs
the same seeded iterations on a bandwidth-starved topology twice —
links priced at fp32 vs. with the full codec menu (bf16/int8/top-k
under a fidelity budget) — and reports ``bytes_on_wire_reduction``
(raw bytes / encoded bytes actually sent) plus the simulated
WAN-row throughput gain (completed microbatches per simulated
second).  Both are ratios of simulated quantities, so the smoke gate
on them is host-independent.

An **adversarial straggler record** (``adversarial`` key in the JSON)
runs the same seeded iterations twice against a 10%-straggler
adversary (one pathologically slow relay per stage, slowdowns far past
the deadline-catchable threshold): once with the engine's deadline
defense (hedged re-dispatch at the healthy-estimate deadline) and once
with ``deadline_defense=False`` (the sender waits out the slowed
compute).  It reports ``defense_throughput_gain`` — defended vs
undefended completed microbatches per *simulated* second — plus the
defended run's straggler detection/repair counts from the shared
``FaultTimeline``.  The gain is a ratio of simulated quantities, so
the smoke gate on it is host-independent.

Results go to ``BENCH_sim.json`` at the repo root.  ``--smoke`` runs
the small size only and compares against the committed JSON: it exits
non-zero if the engine's events/sec regressed by more than 2x
(host-normalized by the reference loop's events/sec measured in the
same run), if GWTF equivalence broke, if the WAN record's
``bytes_on_wire_reduction`` fell below the committed floor, or if the
adversarial record's defense gain fell below its floor (2x, or the
committed gain if lower).
Numpy-only on purpose — the CI smoke job stays light.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.flow.graph import geo_distributed_network
from repro.core.sim import TrainingSimulator
from repro.core.sim.faults import StragglerChurn
from repro.core.sim.reference import ReferenceTrainingSimulator

REPO_ROOT = Path(__file__).resolve().parent.parent
DEFAULT_OUT = REPO_ROOT / "BENCH_sim.json"

STAGES = 10
DATA_NODES = 2
DATA_CAPACITY = 100
CHURN = 0.05
ITERATIONS = 5
SEED = 0
FULL_SIZES = (200, 1000)
SMOKE_SIZES = (200,)

# WAN compression record: bandwidth-starved links (vs the default
# 50-500 Mb/s grid) so transfer time dominates and the planner prices
# its way down to the aggressive codecs; the smoke gate's bytes floor
# is a ratio of simulated quantities and therefore host-independent.
WAN_RELAYS = 200
WAN_MIN_BANDWIDTH = 2e6       # bytes/s
WAN_MAX_BANDWIDTH = 1e7
WAN_MENU = ("fp32", "bf16", "int8", "top-k")
WAN_FIDELITY_BUDGET = 0.1
WAN_BYTES_REDUCTION_FLOOR = 3.0

# Adversarial straggler record: one slow relay per stage (10% of the
# 60-relay topology), slowdown chosen so the slowed compute blows far
# past the healthy-estimate deadline (compute floor 0.5s x (400-1)
# >> 30s timeout) — i.e. squarely in the deadline-catchable regime.
# The smoke gate's throughput gain is a ratio of *simulated* seconds
# and therefore host-independent.
ADV_RELAYS = 60
ADV_SLOWDOWN = 400.0
ADV_GAIN_FLOOR = 2.0


def build_network(relays: int, seed: int = SEED):
    """Geo-distributed topology scaled up from the paper's Sec. VI grid:
    heterogeneous caps U{1..3}, 10 locations, 50-500 Mb/s links."""
    rng = np.random.default_rng(seed)
    caps = [int(rng.uniform(1, 4)) for _ in range(relays)]
    return geo_distributed_network(
        num_stages=STAGES, relay_capacities=caps,
        num_data_nodes=DATA_NODES, data_capacity=DATA_CAPACITY,
        compute_cost=0.5, rng=np.random.default_rng(seed))


def _run(cls, relays: int, scheduler: str, seed: int,
         track_optimality: bool = False):
    net = build_network(relays, seed)
    rng = np.random.default_rng(seed + 11)
    if track_optimality and cls is TrainingSimulator and scheduler == "gwtf":
        # same construction order as make_policy — identical RNG stream
        from repro.core.sim.policies import GWTFPolicy
        sim = cls(net, policy=GWTFPolicy(net, rng=rng,
                                         track_optimality=True),
                  churn=CHURN, rng=rng)
    else:
        sim = cls(net, scheduler=scheduler, churn=CHURN, rng=rng)
    t0 = time.perf_counter()
    ms = sim.run(ITERATIONS)
    total_s = time.perf_counter() - t0
    return dict(
        pops=sum(m.events for m in ms),
        loop_s=sum(m.loop_seconds for m in ms),
        plan_s=sum(m.plan_seconds for m in ms),
        per_iter=[(round(m.plan_seconds, 4), round(m.loop_seconds, 4))
                  for m in ms],
        cost_ratio=[None if getattr(m, "cost_ratio_vs_optimal", None)
                    is None else round(m.cost_ratio_vs_optimal, 4)
                    for m in ms],
        total_s=total_s,
        launched=sum(m.launched for m in ms),
        completed=sum(m.completed for m in ms),
        comm_time=sum(m.comm_time for m in ms),
        wasted_gpu=sum(m.wasted_gpu for m in ms),
        duration=sum(m.duration for m in ms),
    )


def bench_size(relays: int, seed: int = SEED, profile: bool = False) -> dict:
    rec = dict(relays=relays, stages=STAGES, churn=CHURN,
               iterations=ITERATIONS, schedulers={})
    for scheduler in ("gwtf", "swarm"):
        eng = _run(TrainingSimulator, relays, scheduler, seed,
                   track_optimality=profile)
        ref = _run(ReferenceTrainingSimulator, relays, scheduler, seed)
        canonical = ref["pops"]
        cell = dict(
            canonical_events=canonical,
            engine_pops=eng["pops"],
            engine_loop_s=round(eng["loop_s"], 4),
            engine_plan_s=round(eng["plan_s"], 4),
            ref_loop_s=round(ref["loop_s"], 4),
            engine_events_per_sec=round(canonical / eng["loop_s"], 1),
            ref_events_per_sec=round(canonical / ref["loop_s"], 1),
            loop_speedup=round(ref["loop_s"] / eng["loop_s"], 2),
            completed=(eng["completed"], ref["completed"]),
        )
        if profile:
            cell["per_iter_plan_loop_s"] = eng["per_iter"]
            if scheduler == "gwtf":
                # online dial-oracle optimality gap of every plan
                # (GWTFPolicy(track_optimality=True); None = no flows
                # or non-finite costs)
                cell["cost_ratio_vs_optimal"] = eng["cost_ratio"]
        if scheduler == "gwtf":
            cell["metrics_identical"] = (
                eng["completed"] == ref["completed"]
                and eng["comm_time"] == ref["comm_time"]
                and eng["wasted_gpu"] == ref["wasted_gpu"]
                and eng["duration"] == ref["duration"])
        rec["schedulers"][scheduler] = cell
    return rec


def print_rec(rec: dict):
    for scheduler, c in rec["schedulers"].items():
        eq = c.get("metrics_identical")
        print(f"  relays={rec['relays']:5d} {scheduler:5s}: "
              f"engine={c['engine_events_per_sec']:10,.0f} ev/s  "
              f"ref={c['ref_events_per_sec']:10,.0f} ev/s  "
              f"speedup={c['loop_speedup']:5.2f}x  "
              f"plan={c['engine_plan_s']:6.2f}s loop={c['engine_loop_s']:6.3f}s  "
              f"{'identical' if eq else ('EQUIV-FAIL' if eq is False else '')}")
        per_iter = c.get("per_iter_plan_loop_s")
        if per_iter:
            ratios = c.get("cost_ratio_vs_optimal") or [None] * len(per_iter)
            for k, (p, l) in enumerate(per_iter):
                frac = p / (p + l) if (p + l) > 0 else 0.0
                r = ratios[k] if k < len(ratios) else None
                gap = f"  cost/optimal={r:.4f}" if r is not None else ""
                print(f"      iter {k}: plan={p:7.4f}s  loop={l:7.4f}s  "
                      f"planning {100 * frac:5.1f}% of iteration{gap}")


def bench_wan(relays: int = WAN_RELAYS, seed: int = SEED) -> dict:
    """fp32-priced vs codec-priced runs of the same seeded iterations on
    the bandwidth-starved WAN topology; all reported ratios are between
    simulated quantities (bytes, simulated seconds)."""
    def run(with_codecs: bool) -> dict:
        rng = np.random.default_rng(seed)
        caps = [int(rng.uniform(1, 4)) for _ in range(relays)]
        net = geo_distributed_network(
            num_stages=STAGES, relay_capacities=caps,
            num_data_nodes=DATA_NODES, data_capacity=DATA_CAPACITY,
            compute_cost=0.5,
            min_bandwidth=WAN_MIN_BANDWIDTH,
            max_bandwidth=WAN_MAX_BANDWIDTH,
            rng=np.random.default_rng(seed))
        if with_codecs:
            net.codec_menu = WAN_MENU
            net.fidelity_budget = WAN_FIDELITY_BUDGET
        sim = TrainingSimulator(net, scheduler="gwtf", churn=CHURN,
                                rng=np.random.default_rng(seed + 11))
        ms = sim.run(ITERATIONS)
        legs: dict = {}
        for m in ms:
            for name, cnt in (m.codec_legs or {}).items():
                legs[name] = legs.get(name, 0) + cnt
        return dict(bytes=sum(m.bytes_on_wire for m in ms),
                    duration=sum(m.duration for m in ms),
                    completed=sum(m.completed for m in ms),
                    comm_time=sum(m.comm_time for m in ms),
                    codec_legs=legs)
    fp32, codec = run(False), run(True)
    fp32_tp = fp32["completed"] / fp32["duration"]
    codec_tp = codec["completed"] / codec["duration"]
    return dict(
        relays=relays, stages=STAGES, churn=CHURN, iterations=ITERATIONS,
        min_bandwidth=WAN_MIN_BANDWIDTH, max_bandwidth=WAN_MAX_BANDWIDTH,
        menu=list(WAN_MENU), fidelity_budget=WAN_FIDELITY_BUDGET,
        bytes_on_wire_fp32=fp32["bytes"],
        bytes_on_wire_codec=codec["bytes"],
        bytes_on_wire_reduction=round(fp32["bytes"] / codec["bytes"], 2),
        codec_legs=codec["codec_legs"],
        completed=(fp32["completed"], codec["completed"]),
        comm_time=(round(fp32["comm_time"], 2), round(codec["comm_time"], 2)),
        mb_per_sim_sec_fp32=round(fp32_tp, 4),
        mb_per_sim_sec_codec=round(codec_tp, 4),
        sim_throughput_gain=round(codec_tp / fp32_tp, 2))


def print_wan(rec: dict):
    print(f"  wan relays={rec['relays']:5d}: bytes "
          f"{rec['bytes_on_wire_fp32'] / 1e9:.2f}GB -> "
          f"{rec['bytes_on_wire_codec'] / 1e9:.2f}GB "
          f"({rec['bytes_on_wire_reduction']:.2f}x reduction)  "
          f"throughput {rec['mb_per_sim_sec_fp32']:.4f} -> "
          f"{rec['mb_per_sim_sec_codec']:.4f} mb/sim-s "
          f"({rec['sim_throughput_gain']:.2f}x)  legs={rec['codec_legs']}")


def bench_adversarial(relays: int = ADV_RELAYS, seed: int = SEED) -> dict:
    """Deadline-defended vs undefended runs of the same seeded
    iterations against a 10% straggler adversary; the reported gain is
    a ratio of simulated quantities (completed microbatches, simulated
    seconds), so it is host-independent."""
    per_stage = relays // STAGES
    slow_nodes = [DATA_NODES + s * per_stage for s in range(STAGES)]

    def run(defended: bool) -> dict:
        net = build_network(relays, seed)
        model = StragglerChurn({n: ADV_SLOWDOWN for n in slow_nodes},
                               known_ids=net.nodes.keys())
        sim = TrainingSimulator(net, scheduler="gwtf", churn_model=model,
                                rng=np.random.default_rng(seed + 11),
                                deadline_defense=defended)
        ms = sim.run(ITERATIONS)
        counts = sim.engine.timeline.counts()
        detections = sum(c for (_, fault, kind), c in counts.items()
                         if fault == "straggler" and kind == "detection")
        repairs = sum(c for (_, fault, kind), c in counts.items()
                      if fault == "straggler" and kind == "repair")
        return dict(completed=sum(m.completed for m in ms),
                    duration=sum(m.duration for m in ms),
                    timeouts=sum(m.timeouts for m in ms),
                    retries=sum(m.retries for m in ms),
                    detections=detections, repairs=repairs)

    defended, undefended = run(True), run(False)
    def_tp = defended["completed"] / defended["duration"]
    undef_tp = undefended["completed"] / undefended["duration"]
    return dict(
        relays=relays, stages=STAGES, iterations=ITERATIONS,
        straggler_nodes=slow_nodes, slowdown=ADV_SLOWDOWN,
        completed=(defended["completed"], undefended["completed"]),
        duration=(round(defended["duration"], 2),
                  round(undefended["duration"], 2)),
        timeouts=(defended["timeouts"], undefended["timeouts"]),
        retries=(defended["retries"], undefended["retries"]),
        straggler_detections=defended["detections"],
        straggler_repairs=defended["repairs"],
        mb_per_sim_sec_defended=round(def_tp, 4),
        mb_per_sim_sec_undefended=round(undef_tp, 4),
        defense_throughput_gain=round(def_tp / undef_tp, 2))


def print_adversarial(rec: dict):
    print(f"  adversarial relays={rec['relays']:5d} "
          f"({len(rec['straggler_nodes'])} stragglers x"
          f"{rec['slowdown']:.0f}): throughput "
          f"{rec['mb_per_sim_sec_undefended']:.4f} -> "
          f"{rec['mb_per_sim_sec_defended']:.4f} mb/sim-s "
          f"({rec['defense_throughput_gain']:.2f}x defended)  "
          f"detections={rec['straggler_detections']} "
          f"repairs={rec['straggler_repairs']}")


def smoke(committed_path: Path) -> int:
    """CI gate: fail (exit 1) if events/sec regressed > 2x vs committed
    (host-normalized via the reference loop), GWTF equivalence broke, or
    the WAN record's bytes-on-wire reduction fell below the committed
    floor (the bytes ratio is simulated, so no host normalization)."""
    if not committed_path.exists():
        print(f"no committed {committed_path.name}; smoke run is "
              f"informational only")
        committed = {}
    else:
        data = json.loads(committed_path.read_text())
        committed = {r["relays"]: r for r in data["results"]}
    failures = []
    print(f"== bench_sim --smoke (sizes {SMOKE_SIZES}) ==")
    for relays in SMOKE_SIZES:
        # best-of-3: the engine loop at smoke size is tens of
        # milliseconds, so a background load spike can halve a single
        # ev/s sample; taking each implementation's best sample keeps
        # the host-normalized gate meaningful on noisy CI machines
        recs = [bench_size(relays) for _ in range(3)]
        rec = recs[0]
        for scheduler in rec["schedulers"]:
            cells = [r["schedulers"][scheduler] for r in recs]
            best = max(cells, key=lambda c: c["engine_events_per_sec"])
            best_ref = max(c["ref_events_per_sec"] for c in cells)
            merged = dict(best, ref_events_per_sec=best_ref)
            if any("metrics_identical" in c for c in cells):
                merged["metrics_identical"] = all(
                    c.get("metrics_identical") for c in cells)
            rec["schedulers"][scheduler] = merged
        print_rec(rec)
        for scheduler, cell in rec["schedulers"].items():
            # planning-vs-loop split in the CI log: a planning-side
            # regression shows up here even when events/sec holds
            tot = cell["engine_plan_s"] + cell["engine_loop_s"]
            frac = cell["engine_plan_s"] / tot if tot > 0 else 0.0
            print(f"    profile[{scheduler}]: plan {cell['engine_plan_s']:.2f}s"
                  f" / loop {cell['engine_loop_s']:.3f}s "
                  f"({100 * frac:.0f}% planning)")
        for scheduler, cell in rec["schedulers"].items():
            if cell.get("metrics_identical") is False:
                failures.append(f"relays={relays} {scheduler}: engine "
                                f"metrics diverged from reference loop")
                continue
            base = committed.get(relays, {}).get("schedulers", {}).get(scheduler)
            if base is None:
                continue
            host = cell["ref_events_per_sec"] / base["ref_events_per_sec"]
            floor = base["engine_events_per_sec"] * host / 2.0
            print(f"    gate[{scheduler}]: measured "
                  f"{cell['engine_events_per_sec']:,.0f} ev/s vs floor "
                  f"{floor:,.0f} ev/s (committed "
                  f"{base['engine_events_per_sec']:,.0f} x host "
                  f"{host:.2f} / 2)")
            if cell["engine_events_per_sec"] < floor:
                failures.append(
                    f"relays={relays} {scheduler}: events/sec regressed >2x "
                    f"({cell['engine_events_per_sec']:,.0f} < "
                    f"floor {floor:,.0f})")
    wan = bench_wan()
    print_wan(wan)
    if committed_path.exists():
        committed_wan = json.loads(committed_path.read_text()).get("wan")
    else:
        committed_wan = None
    wan_floor = WAN_BYTES_REDUCTION_FLOOR
    if committed_wan is not None:
        # never gate below what the committed record actually achieved
        wan_floor = min(wan_floor, committed_wan["bytes_on_wire_reduction"])
    print(f"    gate[wan]: bytes_on_wire_reduction "
          f"{wan['bytes_on_wire_reduction']:.2f}x vs floor "
          f"{wan_floor:.2f}x (simulated ratio, host-independent)")
    if wan["bytes_on_wire_reduction"] < wan_floor:
        failures.append(
            f"wan: bytes_on_wire_reduction {wan['bytes_on_wire_reduction']:.2f}x "
            f"< floor {wan_floor:.2f}x")
    if wan["sim_throughput_gain"] < 1.0:
        failures.append(
            f"wan: codec pricing made simulated throughput worse "
            f"({wan['sim_throughput_gain']:.2f}x)")
    adv = bench_adversarial()
    print_adversarial(adv)
    if committed_path.exists():
        committed_adv = json.loads(committed_path.read_text()).get("adversarial")
    else:
        committed_adv = None
    adv_floor = ADV_GAIN_FLOOR
    if committed_adv is not None:
        # never gate below what the committed record actually achieved
        adv_floor = min(adv_floor, committed_adv["defense_throughput_gain"])
    print(f"    gate[adversarial]: defense_throughput_gain "
          f"{adv['defense_throughput_gain']:.2f}x vs floor "
          f"{adv_floor:.2f}x (simulated ratio, host-independent)")
    if adv["defense_throughput_gain"] < adv_floor:
        failures.append(
            f"adversarial: defense_throughput_gain "
            f"{adv['defense_throughput_gain']:.2f}x < floor {adv_floor:.2f}x")
    if adv["straggler_detections"] == 0:
        failures.append(
            "adversarial: deadline defense produced zero straggler "
            "detections — the defended run never caught a straggler")
    if failures:
        print("SMOKE FAILURES:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("smoke OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small size + regression gate vs committed JSON")
    ap.add_argument("--sizes", type=int, nargs="*", default=None)
    ap.add_argument("--profile", action="store_true",
                    help="report per-iteration planning vs event-loop "
                         "wall-time split")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.smoke:
        return smoke(args.out)

    sizes = tuple(args.sizes) if args.sizes else FULL_SIZES
    print(f"== bench_sim: {STAGES} stages, {DATA_NODES}x{DATA_CAPACITY} "
          f"data capacity, churn {CHURN}, sizes {sizes} ==")
    results = []
    for relays in sizes:
        rec = bench_size(relays, profile=args.profile)
        print_rec(rec)
        results.append(rec)
    wan = bench_wan()
    print_wan(wan)
    adv = bench_adversarial()
    print_adversarial(adv)
    out = dict(
        meta=dict(stages=STAGES, data_nodes=DATA_NODES,
                  data_capacity=DATA_CAPACITY, churn=CHURN,
                  iterations=ITERATIONS, seed=SEED,
                  metric="canonical calendar events (pre-refactor loop's "
                         "count) per second of event-loop wall time; "
                         "reference = repro.core.sim.reference on "
                         "identical seeded iterations; wan = fp32-priced "
                         "vs codec-priced bytes on wire and simulated "
                         "throughput on a bandwidth-starved topology; "
                         "adversarial = deadline-defended vs undefended "
                         "simulated throughput under a 10% straggler "
                         "adversary"),
        results=results, wan=wan, adversarial=adv)
    args.out.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
