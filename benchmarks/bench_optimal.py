"""Paper Table VI: GWTF vs the DT-FM communication-optimal schedule.

Setup mirrors the 0% homogeneous setting with 3 dataholders and relays in
stages (GPipe-style, 4 microbatches per pipeline).  The DT-FM baseline is
the centralized optimum: min-cost-flow paths computed with global
knowledge and simulated as fixed pipelines.  Paper: optimal beats GWTF by
~13% on time/microbatch while being exponentially more expensive to
compute; GWTF approaches it.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core.flow.graph import geo_distributed_network
from repro.core.flow.mincost import solve_training_flow
from repro.core.simulator import ModelProfile, TrainingSimulator


def run(reps: int = 5, iterations: int = 10, verbose: bool = True):
    cfg = get_config("gwtf-llama-300m")
    stages = 4
    prof = ModelProfile.from_config(cfg, num_stages=stages)
    res = {"gwtf": ([], []), "dtfm": ([], [])}
    for rep in range(reps):
        net = geo_distributed_network(
            num_stages=stages, relay_capacities=[4] * 16,
            num_data_nodes=3, data_capacity=4,
            compute_cost=prof.fwd_compute,
            activation_size=prof.activation_bytes,
            rng=np.random.default_rng(rep))
        # --- DT-FM: centralized optimal paths, fixed pipelines ----------
        plan = solve_training_flow(net, want_paths=True)
        sim_opt = TrainingSimulator(net, scheduler="fixed",
                                    fixed_paths=plan.paths, profile=prof,
                                    churn=0.0,
                                    rng=np.random.default_rng(rep + 50))
        ms = sim_opt.run(iterations)[1:]
        res["dtfm"][0].append(np.mean([m.time_per_microbatch for m in ms]))
        res["dtfm"][1].append(np.mean([m.completed for m in ms]))
        # --- GWTF --------------------------------------------------------
        sim_g = TrainingSimulator(net, scheduler="gwtf", profile=prof,
                                  churn=0.0,
                                  rng=np.random.default_rng(rep + 90))
        ms = sim_g.run(iterations)[1:]
        res["gwtf"][0].append(np.mean([m.time_per_microbatch for m in ms]))
        res["gwtf"][1].append(np.mean([m.completed for m in ms]))

    rows = []
    if verbose:
        print("\n=== Table VI — GWTF vs DT-FM optimal schedule ===")
    for name in ("dtfm", "gwtf"):
        t = np.mean(res[name][0])
        th = np.mean(res[name][1])
        if verbose:
            print(f"{name:6s} time/microbatch={t:7.2f}s ± "
                  f"{np.std(res[name][0]):.2f}  throughput={th:5.2f}")
        rows.append(csv_row(f"tableVI_{name}_time_per_mb_s", t,
                            f"throughput={th:.2f}"))
    gap = (np.mean(res["gwtf"][0]) - np.mean(res["dtfm"][0])) / \
        max(np.mean(res["dtfm"][0]), 1e-9)
    if verbose:
        print(f"GWTF gap to optimal: {gap:+.1%} (paper: ~13%)")
    rows.append(csv_row("tableVI_gwtf_gap_to_optimal", gap))
    return rows


if __name__ == "__main__":
    for line in run():
        print(line)
